//! Bipartite graphs and their reduction to max-flow (paper §4.1, Table 2:
//! a super source feeds the left part, the right part drains into a super
//! sink, all capacities 1 — maximum flow = maximum matching).

use super::builder::FlowNetwork;
use super::{Edge, VertexId};
use crate::util::Rng;

/// A bipartite graph: left part `0..nl`, right part `0..nr`, edges between.
#[derive(Debug, Clone)]
pub struct BipartiteGraph {
    pub nl: usize,
    pub nr: usize,
    /// `(l, r)` with `l < nl`, `r < nr`.
    pub edges: Vec<(VertexId, VertexId)>,
    pub name: String,
}

impl BipartiteGraph {
    pub fn new(nl: usize, nr: usize, mut edges: Vec<(VertexId, VertexId)>, name: impl Into<String>) -> BipartiteGraph {
        edges.sort_unstable();
        edges.dedup();
        let g = BipartiteGraph { nl, nr, edges, name: name.into() };
        g.validate().expect("invalid bipartite graph");
        g
    }

    pub fn validate(&self) -> Result<(), String> {
        for &(l, r) in &self.edges {
            if l as usize >= self.nl || r as usize >= self.nr {
                return Err(format!("edge ({l},{r}) out of range ({}, {})", self.nl, self.nr));
            }
        }
        Ok(())
    }

    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// Reduce to a unit-capacity flow network:
    /// vertex ids: left `0..nl`, right `nl..nl+nr`, source `nl+nr`,
    /// sink `nl+nr+1`.
    pub fn to_flow_network(&self) -> FlowNetwork {
        let s = (self.nl + self.nr) as VertexId;
        let t = s + 1;
        let mut edges = Vec::with_capacity(self.nl + self.nr + self.edges.len());
        for l in 0..self.nl {
            edges.push(Edge::new(s, l as VertexId, 1));
        }
        for &(l, r) in &self.edges {
            edges.push(Edge::new(l, self.nl as VertexId + r, 1));
        }
        for r in 0..self.nr {
            edges.push(Edge::new(self.nl as VertexId + r as VertexId, t, 1));
        }
        FlowNetwork::new(self.nl + self.nr + 2, s, t, edges, format!("{}-flow", self.name))
    }
}

/// KONECT-analog generator: `m` edges with Zipf-skewed endpoints on both
/// sides (`skew = 0.0` gives uniform). The paper's B7/B8 (YouTube,
/// DBpedia) are highly skewed; B0-B2 are tiny and near-uniform.
pub fn bipartite_zipf(nl: usize, nr: usize, m: usize, skew: f64, seed: u64) -> BipartiteGraph {
    assert!(nl >= 1 && nr >= 1);
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m);
    // Random side permutations so the Zipf head isn't always vertex 0..k —
    // keeps analog graphs from looking artificially sorted.
    let mut lperm: Vec<u32> = (0..nl as u32).collect();
    let mut rperm: Vec<u32> = (0..nr as u32).collect();
    rng.shuffle(&mut lperm);
    rng.shuffle(&mut rperm);
    for _ in 0..m {
        let l = if skew > 0.0 { rng.zipf(nl, skew) } else { rng.index(nl) };
        let r = if skew > 0.0 { rng.zipf(nr, skew) } else { rng.index(nr) };
        edges.push((lperm[l], rperm[r]));
    }
    BipartiteGraph::new(nl, nr, edges, format!("bipartite(nl={nl},nr={nr},m={m},skew={skew},seed={seed})"))
}

/// A bipartite graph with a known perfect-on-the-left matching (planted),
/// useful as a correctness oracle for the matching pipeline.
pub fn bipartite_planted(nl: usize, nr: usize, extra: usize, seed: u64) -> BipartiteGraph {
    assert!(nl <= nr);
    let mut rng = Rng::new(seed);
    let mut rperm: Vec<u32> = (0..nr as u32).collect();
    rng.shuffle(&mut rperm);
    let mut edges: Vec<(u32, u32)> = (0..nl).map(|l| (l as u32, rperm[l])).collect();
    for _ in 0..extra {
        edges.push((rng.index(nl) as u32, rng.index(nr) as u32));
    }
    BipartiteGraph::new(nl, nr, edges, format!("planted(nl={nl},nr={nr},extra={extra},seed={seed})"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_shape() {
        let g = BipartiteGraph::new(2, 3, vec![(0, 0), (0, 2), (1, 1)], "tiny");
        let net = g.to_flow_network();
        assert_eq!(net.n, 7);
        assert_eq!(net.m(), 2 + 3 + 3);
        assert_eq!(net.s, 5);
        assert_eq!(net.t, 6);
        net.validate().unwrap();
        // All capacities are 1.
        assert!(net.edges.iter().all(|e| e.cap == 1));
    }

    #[test]
    fn dedup_on_construction() {
        let g = BipartiteGraph::new(2, 2, vec![(0, 0), (0, 0), (1, 1)], "dup");
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn zipf_generator_in_range_and_deterministic() {
        let a = bipartite_zipf(100, 40, 500, 1.1, 9);
        let b = bipartite_zipf(100, 40, 500, 1.1, 9);
        assert_eq!(a.edges, b.edges);
        a.validate().unwrap();
        assert!(a.m() <= 500);
    }

    #[test]
    fn planted_has_left_perfect_matching_edges() {
        let g = bipartite_planted(10, 15, 30, 4);
        g.validate().unwrap();
        // Each left vertex must appear at least once.
        for l in 0..10u32 {
            assert!(g.edges.iter().any(|&(a, _)| a == l));
        }
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let g = BipartiteGraph { nl: 2, nr: 2, edges: vec![(5, 0)], name: "bad".into() };
        assert!(g.validate().is_err());
    }
}
