//! Synthetic workload generators.
//!
//! Two DIMACS 1st-Challenge generators are reimplemented faithfully
//! (Washington-RLG, Genrmf — the paper's S0/S1), and a family of
//! SNAP/KONECT *analogs* provide the degree-distribution regimes of the
//! paper's real-world graphs (see DESIGN.md §4 for the substitution
//! rationale): road-like meshes (R1/R2), near-regular co-purchase graphs
//! (R0), power-law RMAT graphs (R5/R7...), and web-like graphs (R3/R4).

use super::builder::FlowNetwork;
use super::{Capacity, Edge, VertexId};
use crate::util::Rng;

/// Parameters of the DIMACS `genrmf` generator: `b` frames of `a × a` grid
/// vertices; in-frame edges have capacity `c2 * a * a`, inter-frame edges
/// (a random permutation per frame boundary) have capacity uniform in
/// `[c1, c2]`. Source is the first vertex of the first frame, sink the last
/// vertex of the last frame.
#[derive(Debug, Clone)]
pub struct GenrmfParams {
    pub a: usize,
    pub b: usize,
    pub c1: Capacity,
    pub c2: Capacity,
    pub seed: u64,
}

/// DIMACS `genrmf` (Goldfarb–Grigoriadis RMF networks) — the paper's S1.
pub fn genrmf(p: &GenrmfParams) -> FlowNetwork {
    assert!(p.a >= 1 && p.b >= 2 && p.c1 >= 1 && p.c2 >= p.c1);
    let a = p.a;
    let frame = a * a;
    let n = frame * p.b;
    let mut rng = Rng::new(p.seed);
    let idx = |f: usize, x: usize, y: usize| -> VertexId { (f * frame + y * a + x) as VertexId };
    let in_cap = (p.c2 as i64) * (a as i64) * (a as i64);
    let mut edges = Vec::new();
    for f in 0..p.b {
        // In-frame 4-neighborhood, both directions.
        for y in 0..a {
            for x in 0..a {
                if x + 1 < a {
                    edges.push(Edge::new(idx(f, x, y), idx(f, x + 1, y), in_cap));
                    edges.push(Edge::new(idx(f, x + 1, y), idx(f, x, y), in_cap));
                }
                if y + 1 < a {
                    edges.push(Edge::new(idx(f, x, y), idx(f, x, y + 1), in_cap));
                    edges.push(Edge::new(idx(f, x, y + 1), idx(f, x, y), in_cap));
                }
            }
        }
        // Inter-frame random permutation, forward only.
        if f + 1 < p.b {
            let mut perm: Vec<usize> = (0..frame).collect();
            rng.shuffle(&mut perm);
            for (i, &j) in perm.iter().enumerate() {
                let cap = rng.range_i64(p.c1, p.c2);
                edges.push(Edge::new((f * frame + i) as VertexId, ((f + 1) * frame + j) as VertexId, cap));
            }
        }
    }
    FlowNetwork::new(
        n,
        0,
        (n - 1) as VertexId,
        edges,
        format!("genrmf(a={},b={},c1={},c2={},seed={})", p.a, p.b, p.c1, p.c2, p.seed),
    )
}

/// Parameters of the DIMACS Washington random-level-graph generator (RLG) —
/// the paper's S0. `levels` ranks of `width` vertices; every vertex sends
/// `fanout` edges to random vertices of the next level with capacity uniform
/// in `[1, max_cap]`; a super source feeds level 0 and the last level drains
/// into the sink.
#[derive(Debug, Clone)]
pub struct WashingtonParams {
    pub levels: usize,
    pub width: usize,
    pub fanout: usize,
    pub max_cap: Capacity,
    pub seed: u64,
}

/// Washington RLG (random level graph).
pub fn washington_rlg(p: &WashingtonParams) -> FlowNetwork {
    assert!(p.levels >= 1 && p.width >= 1 && p.fanout >= 1 && p.max_cap >= 1);
    let n = p.levels * p.width + 2;
    let s = (n - 2) as VertexId;
    let t = (n - 1) as VertexId;
    let mut rng = Rng::new(p.seed);
    let node = |lvl: usize, i: usize| -> VertexId { (lvl * p.width + i) as VertexId };
    let mut edges = Vec::new();
    for i in 0..p.width {
        edges.push(Edge::new(s, node(0, i), p.max_cap * p.fanout as i64));
    }
    for lvl in 0..p.levels {
        for i in 0..p.width {
            if lvl + 1 < p.levels {
                for _ in 0..p.fanout {
                    let j = rng.index(p.width);
                    edges.push(Edge::new(node(lvl, i), node(lvl + 1, j), rng.range_i64(1, p.max_cap)));
                }
            } else {
                edges.push(Edge::new(node(lvl, i), t, p.max_cap * p.fanout as i64));
            }
        }
    }
    FlowNetwork::new(
        n,
        s,
        t,
        edges,
        format!("washington-rlg(l={},w={},f={},cap={},seed={})", p.levels, p.width, p.fanout, p.max_cap, p.seed),
    )
}

/// R-MAT power-law generator (Chakrabarti et al.) — the analog of the
/// paper's heavy-tailed SNAP graphs (cit-Patents R5, soc-LiveJournal R7,
/// web graphs R3/R4 with suitable parameters). Unit capacities, like the
/// paper's SNAP setup.
#[derive(Debug, Clone)]
pub struct RmatParams {
    /// `n = 1 << scale` vertices.
    pub scale: u32,
    /// `m = edge_factor * n` directed edges (before dedup).
    pub edge_factor: usize,
    /// Quadrant probabilities; must sum to ~1. (0.57, 0.19, 0.19, 0.05) is
    /// the Graph500 default and yields strong degree skew.
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub seed: u64,
}

pub fn rmat(p: &RmatParams) -> FlowNetwork {
    let n = 1usize << p.scale;
    let m = p.edge_factor * n;
    let d = 1.0 - p.a - p.b - p.c;
    assert!(d >= -1e-9, "rmat probabilities exceed 1");
    let mut rng = Rng::new(p.seed);
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        let mut half = n >> 1;
        while half > 0 {
            let r = rng.f64();
            if r < p.a {
                // top-left
            } else if r < p.a + p.b {
                v += half;
            } else if r < p.a + p.b + p.c {
                u += half;
            } else {
                u += half;
                v += half;
            }
            half >>= 1;
        }
        if u != v {
            edges.push(Edge::new(u as VertexId, v as VertexId, 1));
        }
    }
    let net = FlowNetwork {
        n,
        s: 0,
        t: (n - 1) as VertexId,
        edges,
        name: format!("rmat(scale={},ef={},seed={})", p.scale, p.edge_factor, p.seed),
    };
    net.normalized()
}

/// Road-network analog (paper R1/R2: planar meshes, max degree < 10, unit
/// caps): a `w × h` 4-neighbor grid with a fraction of edges knocked out and
/// a few random "highway" shortcuts.
pub fn grid_road(w: usize, h: usize, drop_prob: f64, shortcuts: usize, seed: u64) -> FlowNetwork {
    assert!(w >= 2 && h >= 2);
    let n = w * h;
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize| -> VertexId { (y * w + x) as VertexId };
    let mut edges = Vec::new();
    let both = |edges: &mut Vec<Edge>, a: VertexId, b: VertexId| {
        edges.push(Edge::new(a, b, 1));
        edges.push(Edge::new(b, a, 1));
    };
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w && !rng.chance(drop_prob) {
                both(&mut edges, idx(x, y), idx(x + 1, y));
            }
            if y + 1 < h && !rng.chance(drop_prob) {
                both(&mut edges, idx(x, y), idx(x, y + 1));
            }
        }
    }
    for _ in 0..shortcuts {
        let a = rng.index(n) as VertexId;
        let b = rng.index(n) as VertexId;
        if a != b {
            both(&mut edges, a, b);
        }
    }
    FlowNetwork::new(n, 0, (n - 1) as VertexId, edges, format!("grid-road({w}x{h},seed={seed})")).normalized()
}

/// Near-regular directed graph (paper R0 analog: Amazon co-purchase —
/// "almost all nodes in the same SCC, degrees very close to each other").
/// Every vertex gets out-degree in `[d-1, d+1]`, targets drawn uniformly,
/// plus a Hamiltonian cycle to force one big SCC. Unit capacities.
pub fn near_regular(n: usize, d: usize, seed: u64) -> FlowNetwork {
    assert!(n >= 3 && d >= 1);
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n * (d + 1));
    for u in 0..n {
        edges.push(Edge::new(u as VertexId, ((u + 1) % n) as VertexId, 1));
        let deg = d - 1 + rng.index(3);
        for _ in 0..deg {
            let v = rng.index(n);
            if v != u {
                edges.push(Edge::new(u as VertexId, v as VertexId, 1));
            }
        }
    }
    FlowNetwork::new(n, 0, (n - 1) as VertexId, edges, format!("near-regular(n={n},d={d},seed={seed})")).normalized()
}

/// Erdős–Rényi-style random directed graph for tests: `m` uniform edges,
/// capacities uniform in `[1, max_cap]`.
pub fn erdos_renyi(n: usize, m: usize, max_cap: Capacity, seed: u64) -> FlowNetwork {
    assert!(n >= 2);
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let u = rng.index(n);
        let v = rng.index(n);
        if u != v {
            edges.push(Edge::new(u as VertexId, v as VertexId, rng.range_i64(1, max_cap.max(1))));
        }
    }
    FlowNetwork::new(n, 0, (n - 1) as VertexId, edges, format!("er(n={n},m={m},seed={seed})")).normalized()
}

/// Web-graph analog (paper R3/R4: web-BerkStan, web-Google — power law with
/// locality): RMAT skeleton plus intra-"site" cliquelets.
pub fn webgraph(scale: u32, edge_factor: usize, seed: u64) -> FlowNetwork {
    let base = rmat(&RmatParams { scale, edge_factor, a: 0.6, b: 0.15, c: 0.15, seed });
    let n = base.n;
    let mut rng = Rng::new(seed ^ 0xDEAD_BEEF);
    let mut edges = base.edges;
    // Link consecutive ids in small blocks (site-local navigation links).
    let mut u = 0usize;
    while u + 1 < n {
        let block = 2 + rng.index(6);
        for i in u..(u + block - 1).min(n - 1) {
            edges.push(Edge::new(i as VertexId, (i + 1) as VertexId, 1));
            if rng.chance(0.5) {
                edges.push(Edge::new((i + 1) as VertexId, i as VertexId, 1));
            }
        }
        u += block;
    }
    FlowNetwork { n, s: base.s, t: base.t, edges, name: format!("webgraph(scale={scale},ef={edge_factor},seed={seed})") }
        .normalized()
}

/// Star-overlay hub network: every unit of flow funnels through one
/// in-hub and one out-hub, each with a `leaves`-arc row — the degenerate
/// power-law case where vertex-granular work assignment serializes a
/// single worker on an O(leaves) scan while the rest idle (the regime the
/// cooperative hub discharge targets). `extra_edges` random leaf-to-leaf
/// arcs add residual structure so the instance is not a pure matching.
///
/// Layout: `s = 0`, `t = 1`, in-hub `2`, out-hub `3`, leaves `4..4+leaves`.
pub fn star_hub(leaves: usize, extra_edges: usize, seed: u64) -> FlowNetwork {
    assert!(leaves >= 2);
    let mut rng = Rng::new(seed);
    let n = 4 + leaves;
    let mut edges = Vec::with_capacity(2 * leaves + extra_edges + 2);
    let big = 4 * leaves as Capacity;
    edges.push(Edge::new(0, 2, big));
    edges.push(Edge::new(3, 1, big));
    for i in 0..leaves {
        let leaf = (4 + i) as VertexId;
        edges.push(Edge::new(2, leaf, rng.range_i64(1, 8)));
        edges.push(Edge::new(leaf, 3, rng.range_i64(1, 8)));
    }
    for _ in 0..extra_edges {
        let u = (4 + rng.index(leaves)) as VertexId;
        let v = (4 + rng.index(leaves)) as VertexId;
        if u != v {
            edges.push(Edge::new(u, v, rng.range_i64(1, 6)));
        }
    }
    FlowNetwork::new(n, 0, 1, edges, format!("star_hub(leaves={leaves},extra={extra_edges},seed={seed})"))
        .normalized()
}

/// Parameters of the deterministic update-stream generator.
///
/// Operation mix is given as probabilities; the remainder
/// (`1 - p_increase - p_decrease - p_insert`) is the delete share.
#[derive(Debug, Clone)]
pub struct UpdateStreamParams {
    pub batches: usize,
    /// Updates per batch (the benches use ~1% of `|E|`).
    pub batch_size: usize,
    pub p_increase: f64,
    pub p_decrease: f64,
    pub p_insert: f64,
    /// Capacity deltas drawn uniformly from `[1, max_delta]`.
    pub max_delta: Capacity,
    pub seed: u64,
}

impl UpdateStreamParams {
    /// Pure capacity churn (no topology changes), `frac`·|E| updates per
    /// batch — the workload of the Table 3 acceptance criterion.
    pub fn capacity_only(m: usize, batches: usize, frac: f64, max_delta: Capacity, seed: u64) -> UpdateStreamParams {
        UpdateStreamParams {
            batches,
            batch_size: ((m as f64 * frac).round() as usize).max(1),
            p_increase: 0.5,
            p_decrease: 0.5,
            p_insert: 0.0,
            max_delta,
            seed,
        }
    }

    /// Topology-heavy churn: half the updates attach or detach edges
    /// (25% inserts, 25% deletes), half edit capacities. `frac`·|E|
    /// updates per batch — the workload of the Table 3 topology arm
    /// (deletes may hit previously deleted or fresh-inserted edges;
    /// real churn looks exactly like that).
    pub fn churn(m: usize, batches: usize, frac: f64, max_delta: Capacity, seed: u64) -> UpdateStreamParams {
        UpdateStreamParams {
            batches,
            batch_size: ((m as f64 * frac).round() as usize).max(1),
            p_increase: 0.25,
            p_decrease: 0.25,
            p_insert: 0.25,
            max_delta,
            seed,
        }
    }
}

/// A sliding-window topology stream: every batch inserts `per_batch` new
/// edges, and once more than `window` batches of inserts are live, also
/// deletes the `per_batch` edges inserted `window` batches ago — the
/// classic streaming-graph window (newest edges arrive, oldest expire).
/// Worst case for a rebuild-per-batch engine: *every* batch changes
/// topology, and the live edge set never stops moving.
///
/// Deterministic in `seed`; indices follow the engine's in-order
/// semantics (inserts append, deletes tombstone in place), so the stream
/// replays against [`crate::dynamic::DynamicFlow`] or
/// [`crate::dynamic::UpdateBatch::apply_to_network`] alike.
pub fn sliding_window_stream(
    net: &FlowNetwork,
    batches: usize,
    per_batch: usize,
    window: usize,
    max_delta: Capacity,
    seed: u64,
) -> crate::dynamic::UpdateStream {
    assert!(per_batch >= 1 && window >= 1);
    let mut rng = Rng::new(seed);
    let mut m = net.edges.len();
    // FIFO of per-batch insert index runs awaiting expiry.
    let mut live: std::collections::VecDeque<Vec<usize>> = std::collections::VecDeque::new();
    let mut out = Vec::with_capacity(batches);
    for _ in 0..batches {
        let mut ups = Vec::with_capacity(2 * per_batch);
        let mut born = Vec::with_capacity(per_batch);
        for _ in 0..per_batch {
            let u = rng.index(net.n) as VertexId;
            let mut v = rng.index(net.n) as VertexId;
            while v == u {
                v = rng.index(net.n) as VertexId;
            }
            ups.push(crate::dynamic::GraphUpdate::InsertEdge { u, v, cap: rng.range_i64(1, max_delta) });
            born.push(m);
            m += 1;
        }
        live.push_back(born);
        if live.len() > window {
            for edge in live.pop_front().unwrap() {
                ups.push(crate::dynamic::GraphUpdate::DeleteEdge { edge });
            }
        }
        out.push(crate::dynamic::UpdateBatch::new(ups));
    }
    crate::dynamic::UpdateStream {
        name: format!(
            "sliding-window(b={batches},per={per_batch},w={window},seed={seed}) over {}",
            net.name
        ),
        batches: out,
    }
}

/// Generate a deterministic stream of update batches for `net`.
///
/// `net` must be in normalized form (sorted, merged, loop-free — what
/// [`FlowNetwork::normalized`] returns and what
/// [`crate::dynamic::DynamicFlow::network`] exposes), because the stream's
/// edge indices address *that* edge list; a raw generator output with
/// parallel edges would make the indices silently point at the wrong
/// edges. Asserted below.
///
/// Edge indices track the engine's in-order semantics: inserts append to
/// the edge list, deletes tombstone in place, so index validity only
/// depends on replaying batches in order. Tombstoned edges may be drawn
/// again (a decrease/delete on them is a no-op; an increase regrows them)
/// — real churn looks exactly like that.
pub fn update_stream(net: &FlowNetwork, p: &UpdateStreamParams) -> crate::dynamic::UpdateStream {
    assert!(
        net.edges.windows(2).all(|w| (w[0].u, w[0].v) < (w[1].u, w[1].v))
            && net.edges.iter().all(|e| e.u != e.v),
        "update_stream needs a normalized network (see FlowNetwork::normalized); \
         for a warm engine's post-insert edge list use update_stream_unchecked"
    );
    update_stream_unchecked(net, p)
}

/// [`update_stream`] without the normalized-form assertion, for edge
/// lists that are index-stable but no longer sorted — i.e. a warm
/// [`crate::dynamic::DynamicFlow::network`] after `InsertEdge` updates
/// appended to it. The caller guarantees the list is exactly the one the
/// replaying engine holds.
pub fn update_stream_unchecked(net: &FlowNetwork, p: &UpdateStreamParams) -> crate::dynamic::UpdateStream {
    assert!(p.p_increase + p.p_decrease + p.p_insert <= 1.0 + 1e-9);
    assert!(p.max_delta >= 1);
    let mut rng = Rng::new(p.seed);
    let mut m = net.edges.len();
    let mut batches = Vec::with_capacity(p.batches);
    for _ in 0..p.batches {
        let mut ups = Vec::with_capacity(p.batch_size);
        for _ in 0..p.batch_size {
            let r = rng.f64();
            let up = if r < p.p_increase {
                crate::dynamic::GraphUpdate::IncreaseCap { edge: rng.index(m), delta: rng.range_i64(1, p.max_delta) }
            } else if r < p.p_increase + p.p_decrease {
                crate::dynamic::GraphUpdate::DecreaseCap { edge: rng.index(m), delta: rng.range_i64(1, p.max_delta) }
            } else if r < p.p_increase + p.p_decrease + p.p_insert {
                // Distinct endpoints, avoiding the terminals as tails is
                // not required — any non-loop edge is legal.
                let u = rng.index(net.n) as VertexId;
                let mut v = rng.index(net.n) as VertexId;
                while v == u {
                    v = rng.index(net.n) as VertexId;
                }
                m += 1;
                crate::dynamic::GraphUpdate::InsertEdge { u, v, cap: rng.range_i64(1, p.max_delta) }
            } else {
                crate::dynamic::GraphUpdate::DeleteEdge { edge: rng.index(m) }
            };
            ups.push(up);
        }
        batches.push(crate::dynamic::UpdateBatch::new(ups));
    }
    crate::dynamic::UpdateStream {
        name: format!(
            "stream(b={},sz={},mix={:.2}/{:.2}/{:.2},seed={}) over {}",
            p.batches, p.batch_size, p.p_increase, p.p_decrease, p.p_insert, p.seed, net.name
        ),
        batches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::{Csr, DegreeStats};

    #[test]
    fn genrmf_shape() {
        let g = genrmf(&GenrmfParams { a: 4, b: 3, c1: 1, c2: 100, seed: 7 });
        assert_eq!(g.n, 48);
        // In-frame edges: 3 frames * 2*2*a*(a-1) = 3*48; inter-frame: 2*16.
        assert_eq!(g.m(), 3 * 48 + 2 * 16);
        g.validate().unwrap();
        // Every inter-frame capacity within [c1, c2]; in-frame = c2*a*a.
        for e in &g.edges {
            assert!(e.cap == 100 * 16 || (1..=100).contains(&e.cap));
        }
    }

    #[test]
    fn genrmf_deterministic() {
        let p = GenrmfParams { a: 3, b: 4, c1: 2, c2: 9, seed: 11 };
        assert_eq!(genrmf(&p).edges, genrmf(&p).edges);
    }

    #[test]
    fn washington_shape() {
        let p = WashingtonParams { levels: 5, width: 8, fanout: 3, max_cap: 50, seed: 3 };
        let g = washington_rlg(&p);
        assert_eq!(g.n, 5 * 8 + 2);
        assert_eq!(g.m(), 8 + 4 * 8 * 3 + 8);
        g.validate().unwrap();
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(&RmatParams { scale: 10, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19, seed: 5 });
        let csr = Csr::from_edges(g.n, g.edges.iter().map(|e| (e.u, e.v)));
        let d = DegreeStats::of(&csr);
        assert!(d.cv() > 1.0, "rmat should be heavy-tailed, cv={}", d.cv());
        assert!(g.m() > 1000);
    }

    #[test]
    fn near_regular_is_flat() {
        let g = near_regular(2000, 6, 9);
        let csr = Csr::from_edges(g.n, g.edges.iter().map(|e| (e.u, e.v)));
        let d = DegreeStats::of(&csr);
        assert!(d.cv() < 0.5, "near-regular should be flat, cv={}", d.cv());
    }

    #[test]
    fn grid_road_low_degree() {
        let g = grid_road(30, 30, 0.1, 20, 4);
        let csr = Csr::from_edges(g.n, g.edges.iter().map(|e| (e.u, e.v)));
        let d = DegreeStats::of(&csr);
        assert!(d.max <= 10, "road max degree {} too high", d.max);
    }

    #[test]
    fn generators_validate() {
        webgraph(8, 4, 1).validate().unwrap();
        erdos_renyi(50, 300, 10, 2).validate().unwrap();
    }

    #[test]
    fn update_stream_is_deterministic_and_in_range() {
        use crate::dynamic::GraphUpdate;
        let net = erdos_renyi(40, 200, 8, 3);
        let p = UpdateStreamParams {
            batches: 6,
            batch_size: 10,
            p_increase: 0.4,
            p_decrease: 0.3,
            p_insert: 0.2,
            max_delta: 5,
            seed: 11,
        };
        let a = update_stream(&net, &p);
        let b = update_stream(&net, &p);
        assert_eq!(a.len(), 60);
        assert_eq!(format!("{:?}", a.batches), format!("{:?}", b.batches), "same seed, same stream");
        // Replaying in order, every index must be valid at its position.
        let mut m = net.edges.len();
        for batch in &a.batches {
            for up in &batch.updates {
                match *up {
                    GraphUpdate::IncreaseCap { edge, delta } | GraphUpdate::DecreaseCap { edge, delta } => {
                        assert!(edge < m && (1..=5).contains(&delta));
                    }
                    GraphUpdate::DeleteEdge { edge } => assert!(edge < m),
                    GraphUpdate::InsertEdge { u, v, cap } => {
                        assert!(u != v && (u as usize) < net.n && (v as usize) < net.n && cap >= 1);
                        m += 1;
                    }
                }
            }
        }
    }

    #[test]
    fn unchecked_stream_accepts_post_insert_edge_lists() {
        use crate::dynamic::{DynamicFlow, GraphUpdate, UpdateBatch};
        let net = erdos_renyi(20, 60, 4, 6);
        let mut df = DynamicFlow::new(&net, &Default::default());
        df.apply(&UpdateBatch::new(vec![GraphUpdate::InsertEdge { u: 5, v: 0, cap: 2 }])).unwrap();
        // network() now carries an appended tail; the unchecked variant
        // must keep producing valid in-range streams for it.
        let p = UpdateStreamParams::capacity_only(df.network().m(), 2, 0.05, 3, 1);
        let s = update_stream_unchecked(df.network(), &p);
        assert!(!s.is_empty());
        for b in &s.batches {
            df.apply(b).unwrap();
        }
    }

    #[test]
    fn capacity_only_stream_has_no_topology_changes() {
        let net = erdos_renyi(30, 120, 6, 4);
        let p = UpdateStreamParams::capacity_only(net.m(), 4, 0.01, 3, 9);
        assert_eq!(p.batch_size, 1, "1% of 120ish edges rounds to 1");
        let s = update_stream(&net, &p);
        assert!(s.batches.iter().all(|b| b.inserts() == 0));
        assert!(!s.is_empty());
    }

    #[test]
    fn churn_stream_is_topology_heavy_and_replayable() {
        use crate::dynamic::{DynamicFlow, GraphUpdate};
        let net = erdos_renyi(30, 150, 6, 5);
        let p = UpdateStreamParams::churn(net.m(), 5, 0.1, 4, 17);
        let s = update_stream(&net, &p);
        let topo: usize = s.batches.iter().map(|b| b.inserts()).sum();
        let total = s.len();
        assert!(topo > 0, "churn must contain inserts/deletes");
        assert!(topo * 4 >= total, "~half the mix is topology, got {topo}/{total}");
        let has_delete = s
            .batches
            .iter()
            .flat_map(|b| &b.updates)
            .any(|u| matches!(u, GraphUpdate::DeleteEdge { .. }));
        assert!(has_delete, "the mix includes a delete share");
        // The stream must replay cleanly on a warm engine and stay a
        // verified max flow throughout.
        let mut df = DynamicFlow::new(&net, &Default::default());
        for b in &s.batches {
            df.apply(b).unwrap();
            crate::maxflow::verify(df.arcs(), &df.flow_result()).unwrap();
        }
    }

    #[test]
    fn sliding_window_stream_expires_oldest_inserts() {
        use crate::dynamic::{DynamicFlow, GraphUpdate};
        let net = erdos_renyi(25, 100, 5, 8);
        let m0 = net.m();
        let s = sliding_window_stream(&net, 6, 3, 2, 4, 23);
        assert_eq!(s.batches.len(), 6);
        // First `window` batches are pure inserts; afterwards each batch
        // also expires the batch of inserts from `window` batches ago.
        for (i, b) in s.batches.iter().enumerate() {
            let inserts =
                b.updates.iter().filter(|u| matches!(u, GraphUpdate::InsertEdge { .. })).count();
            let deletes: Vec<usize> = b
                .updates
                .iter()
                .filter_map(|u| match u {
                    GraphUpdate::DeleteEdge { edge } => Some(*edge),
                    _ => None,
                })
                .collect();
            assert_eq!(inserts, 3);
            if i < 2 {
                assert!(deletes.is_empty());
            } else {
                assert_eq!(deletes.len(), 3);
                // Expired edges are exactly the inserts from batch i-2.
                let expect: Vec<usize> = (0..3).map(|k| m0 + 3 * (i - 2) + k).collect();
                assert_eq!(deletes, expect);
            }
        }
        // Replays cleanly and stays verified.
        let mut df = DynamicFlow::new(&net, &Default::default());
        for b in &s.batches {
            df.apply(b).unwrap();
            crate::maxflow::verify(df.arcs(), &df.flow_result()).unwrap();
        }
    }
}
