//! Graph characterization: strongly connected components (Tarjan,
//! iterative) and the structural statistics the paper uses to explain
//! per-graph results (§4.2: "almost all nodes are within the same SCC, and
//! the degrees of these nodes are very close to each other" for Amazon
//! R0). Consumed by `wbpr info` and the router.

use super::csr::Csr;
use super::VertexId;

/// SCC decomposition result.
#[derive(Debug, Clone)]
pub struct SccResult {
    /// Component id per vertex (0-based, reverse topological order).
    pub comp: Vec<u32>,
    /// Number of components.
    pub count: usize,
    /// Size of the largest component.
    pub largest: usize,
}

/// Tarjan's SCC, iterative (explicit stack — safe for deep graphs).
pub fn scc(csr: &Csr) -> SccResult {
    let n = csr.n();
    const UNSET: u32 = u32::MAX;
    let mut index = vec![UNSET; n];
    let mut low = vec![0u32; n];
    let mut on_stack = vec![false; n];
    let mut comp = vec![UNSET; n];
    let mut stack: Vec<u32> = Vec::new();
    let mut next_index = 0u32;
    let mut count = 0u32;

    // Explicit DFS frames: (vertex, next edge offset within row).
    let mut frames: Vec<(u32, usize)> = Vec::new();
    for root in 0..n as u32 {
        if index[root as usize] != UNSET {
            continue;
        }
        frames.push((root, 0));
        while let Some(&mut (u, ref mut ei)) = frames.last_mut() {
            let ui = u as usize;
            if *ei == 0 {
                index[ui] = next_index;
                low[ui] = next_index;
                next_index += 1;
                stack.push(u);
                on_stack[ui] = true;
            }
            let row = csr.row(u);
            let mut descended = false;
            while *ei < row.len() {
                let v = row[*ei] as usize;
                *ei += 1;
                if index[v] == UNSET {
                    frames.push((v as u32, 0));
                    descended = true;
                    break;
                } else if on_stack[v] {
                    low[ui] = low[ui].min(index[v]);
                }
            }
            if descended {
                continue;
            }
            // u finished.
            if low[ui] == index[ui] {
                loop {
                    let w = stack.pop().unwrap();
                    on_stack[w as usize] = false;
                    comp[w as usize] = count;
                    if w == u {
                        break;
                    }
                }
                count += 1;
            }
            frames.pop();
            if let Some(&mut (p, _)) = frames.last_mut() {
                let pi = p as usize;
                low[pi] = low[pi].min(low[ui]);
            }
        }
    }

    let mut sizes = vec![0usize; count as usize];
    for &c in &comp {
        sizes[c as usize] += 1;
    }
    SccResult { comp, count: count as usize, largest: sizes.iter().copied().max().unwrap_or(0) }
}

/// Fraction of vertices inside the largest SCC — the paper's R0 predictor
/// ("naturally balanced" graphs have one giant SCC + flat degrees).
pub fn largest_scc_fraction(n: usize, edges: impl Iterator<Item = (VertexId, VertexId)>) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let csr = Csr::from_edges(n, edges);
    scc(&csr).largest as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn csr_of(n: usize, edges: &[(u32, u32)]) -> Csr {
        Csr::from_edges(n, edges.iter().copied())
    }

    #[test]
    fn single_cycle_is_one_scc() {
        let c = csr_of(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let r = scc(&c);
        assert_eq!(r.count, 1);
        assert_eq!(r.largest, 4);
    }

    #[test]
    fn dag_has_singleton_sccs() {
        let c = csr_of(4, &[(0, 1), (1, 2), (2, 3)]);
        let r = scc(&c);
        assert_eq!(r.count, 4);
        assert_eq!(r.largest, 1);
    }

    #[test]
    fn two_components_plus_bridge() {
        // {0,1} cycle, {2,3} cycle, bridge 1->2.
        let c = csr_of(4, &[(0, 1), (1, 0), (2, 3), (3, 2), (1, 2)]);
        let r = scc(&c);
        assert_eq!(r.count, 2);
        assert_eq!(r.largest, 2);
        assert_ne!(r.comp[0], r.comp[2]);
        assert_eq!(r.comp[0], r.comp[1]);
        assert_eq!(r.comp[2], r.comp[3]);
    }

    #[test]
    fn near_regular_is_one_giant_scc() {
        // The R0 regime: the generator plants a Hamiltonian cycle.
        let g = generators::near_regular(500, 4, 7);
        let frac = largest_scc_fraction(g.n, g.edges.iter().map(|e| (e.u, e.v)));
        assert!(frac > 0.99, "expected giant SCC, got {frac}");
    }

    #[test]
    fn deep_chain_does_not_overflow() {
        // 200k-vertex path: recursive Tarjan would blow the stack.
        let n = 200_000;
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        let c = Csr::from_edges(n, edges.into_iter());
        let r = scc(&c);
        assert_eq!(r.count, n);
    }

    #[test]
    fn empty_graph() {
        let c = csr_of(3, &[]);
        let r = scc(&c);
        assert_eq!(r.count, 3);
        assert_eq!(r.largest, 1);
    }
}
