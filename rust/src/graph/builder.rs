//! Flow-network container, residual-arc arena construction, super-source /
//! super-sink augmentation, and the paper's BFS-based source/sink pair
//! selection (§4.1).

use super::{Capacity, Edge, VertexId};
use crate::util::Rng;

/// A directed capacitated graph with a designated source and sink.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlowNetwork {
    pub n: usize,
    pub s: VertexId,
    pub t: VertexId,
    pub edges: Vec<Edge>,
    /// Human-readable provenance ("genrmf a=8 ...", "snap-analog R5", ...).
    pub name: String,
}

impl FlowNetwork {
    pub fn new(n: usize, s: VertexId, t: VertexId, edges: Vec<Edge>, name: impl Into<String>) -> FlowNetwork {
        let net = FlowNetwork { n, s, t, edges, name: name.into() };
        net.validate().expect("invalid flow network");
        net
    }

    /// Structural sanity: ids in range, s != t, non-negative capacities.
    pub fn validate(&self) -> Result<(), String> {
        if self.s == self.t {
            return Err("source equals sink".into());
        }
        if self.s as usize >= self.n || self.t as usize >= self.n {
            return Err("source/sink out of range".into());
        }
        for (i, e) in self.edges.iter().enumerate() {
            if e.u as usize >= self.n || e.v as usize >= self.n {
                return Err(format!("edge {i} endpoint out of range"));
            }
            if e.cap < 0 {
                return Err(format!("edge {i} has negative capacity"));
            }
        }
        Ok(())
    }

    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// Remove self loops and merge parallel edges (summing capacities).
    /// Mirrors the preprocessing the paper applies to SNAP inputs.
    pub fn normalized(&self) -> FlowNetwork {
        let mut map = std::collections::HashMap::<(u32, u32), i64>::new();
        for e in &self.edges {
            if e.u == e.v {
                continue;
            }
            *map.entry((e.u, e.v)).or_insert(0) += e.cap;
        }
        let mut edges: Vec<Edge> = map.into_iter().map(|((u, v), cap)| Edge { u, v, cap }).collect();
        edges.sort_by_key(|e| (e.u, e.v));
        FlowNetwork { n: self.n, s: self.s, t: self.t, edges, name: self.name.clone() }
    }
}

/// The canonical residual arena shared by all representations: arc `2e`
/// is the forward copy of edge `e`, arc `2e+1` its reverse (cap 0).
#[derive(Debug, Clone)]
pub struct ArcGraph {
    pub n: usize,
    pub s: VertexId,
    pub t: VertexId,
    /// Target vertex of each arc; `len == 2 * edges`.
    pub arc_to: Vec<VertexId>,
    /// Source vertex of each arc (redundant with the CSRs but O(1) handy).
    pub arc_from: Vec<VertexId>,
    /// Initial residual capacity of each arc.
    pub arc_cap: Vec<Capacity>,
}

impl ArcGraph {
    pub fn build(net: &FlowNetwork) -> ArcGraph {
        let m = net.edges.len();
        let mut arc_to = Vec::with_capacity(2 * m);
        let mut arc_from = Vec::with_capacity(2 * m);
        let mut arc_cap = Vec::with_capacity(2 * m);
        for e in &net.edges {
            arc_to.push(e.v);
            arc_from.push(e.u);
            arc_cap.push(e.cap);
            arc_to.push(e.u);
            arc_from.push(e.v);
            arc_cap.push(0);
        }
        ArcGraph { n: net.n, s: net.s, t: net.t, arc_to, arc_from, arc_cap }
    }

    pub fn num_arcs(&self) -> usize {
        self.arc_to.len()
    }

    /// Reverse arc (the paper's `flow_idx` pairing).
    #[inline(always)]
    pub fn rev(a: u32) -> u32 {
        a ^ 1
    }

    /// Bytes of the arena itself (part of the O(V+E) accounting).
    pub fn memory_bytes(&self) -> usize {
        self.arc_to.len() * 4 + self.arc_from.len() * 4 + self.arc_cap.len() * 8
    }
}

/// Attach a super-source feeding `sources` and a super-sink drained by
/// `sinks` (paper §4.1: multi-source multi-sink max flow over 20 BFS-chosen
/// pairs). Super edges get capacity `super_cap` (pass the sum of adjacent
/// capacities, or a large constant for unit-cap graphs).
pub fn add_super_terminals(
    net: &FlowNetwork,
    sources: &[VertexId],
    sinks: &[VertexId],
    super_cap: Capacity,
) -> FlowNetwork {
    assert!(!sources.is_empty() && !sinks.is_empty());
    let ss = net.n as VertexId;
    let tt = net.n as VertexId + 1;
    let mut edges = net.edges.clone();
    for &s in sources {
        edges.push(Edge::new(ss, s, super_cap));
    }
    for &t in sinks {
        edges.push(Edge::new(t, tt, super_cap));
    }
    FlowNetwork {
        n: net.n + 2,
        s: ss,
        t: tt,
        edges,
        name: format!("{}+super({}s,{}t)", net.name, sources.len(), sinks.len()),
    }
}

/// BFS distances over the *original* out-edges (used for pair selection and
/// diameter probes; the residual BFS lives in `maxflow::global_relabel`).
pub fn bfs_dist(n: usize, adj: &super::Csr, start: VertexId) -> Vec<u32> {
    let mut dist = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    dist[start as usize] = 0;
    queue.push_back(start);
    while let Some(u) = queue.pop_front() {
        let du = dist[u as usize];
        for &v in adj.row(u) {
            if dist[v as usize] == u32::MAX {
                dist[v as usize] = du + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

/// The paper's source/sink selection: probe BFS from sampled vertices,
/// keep the pairs whose finite eccentricity lands in the top 25%, and return
/// up to `pairs` (start, farthest) pairs.
pub fn select_pairs(net: &FlowNetwork, pairs: usize, probes: usize, seed: u64) -> Vec<(VertexId, VertexId)> {
    let adj = super::Csr::from_edges(net.n, net.edges.iter().map(|e| (e.u, e.v)));
    let mut rng = Rng::new(seed);
    let mut cands: Vec<(u32, u32, u32)> = Vec::new(); // (dist, from, to)
    for _ in 0..probes.max(pairs) {
        let start = rng.index(net.n) as VertexId;
        let dist = bfs_dist(net.n, &adj, start);
        let mut far = start;
        let mut best = 0;
        for (v, &d) in dist.iter().enumerate() {
            if d != u32::MAX && d > best {
                best = d;
                far = v as VertexId;
            }
        }
        if best > 0 {
            cands.push((best, start, far));
        }
    }
    cands.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    // Top quartile (at least `pairs` candidates when available).
    let take = (cands.len().div_ceil(4)).max(pairs.min(cands.len()));
    let mut out: Vec<(VertexId, VertexId)> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for &(_, a, b) in cands.iter().take(take) {
        if a != b && seen.insert((a, b)) {
            out.push((a, b));
            if out.len() == pairs {
                break;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> FlowNetwork {
        // s=0 -> {1,2} -> t=3
        FlowNetwork::new(
            4,
            0,
            3,
            vec![Edge::new(0, 1, 3), Edge::new(0, 2, 2), Edge::new(1, 3, 2), Edge::new(2, 3, 3)],
            "diamond",
        )
    }

    #[test]
    fn arc_graph_pairs_arcs() {
        let g = ArcGraph::build(&diamond());
        assert_eq!(g.num_arcs(), 8);
        for e in 0..4 {
            let f = 2 * e as u32;
            assert_eq!(ArcGraph::rev(f), f + 1);
            assert_eq!(ArcGraph::rev(f + 1), f);
            assert_eq!(g.arc_to[f as usize], g.arc_from[(f + 1) as usize]);
            assert_eq!(g.arc_from[f as usize], g.arc_to[(f + 1) as usize]);
            assert_eq!(g.arc_cap[(f + 1) as usize], 0);
        }
    }

    #[test]
    fn validate_catches_errors() {
        let mut bad = diamond();
        bad.edges.push(Edge::new(0, 9, 1));
        assert!(bad.validate().is_err());
        let mut neg = diamond();
        neg.edges[0].cap = -1;
        assert!(neg.validate().is_err());
    }

    #[test]
    fn normalized_merges_and_drops_loops() {
        let net = FlowNetwork {
            n: 3,
            s: 0,
            t: 2,
            edges: vec![Edge::new(0, 1, 1), Edge::new(0, 1, 2), Edge::new(1, 1, 5), Edge::new(1, 2, 1)],
            name: "x".into(),
        };
        let norm = net.normalized();
        assert_eq!(norm.edges.len(), 2);
        assert_eq!(norm.edges[0], Edge::new(0, 1, 3));
    }

    #[test]
    fn super_terminals_wire_up() {
        let net = diamond();
        let aug = add_super_terminals(&net, &[0], &[3], 1_000);
        assert_eq!(aug.n, 6);
        assert_eq!(aug.s, 4);
        assert_eq!(aug.t, 5);
        assert_eq!(aug.m(), net.m() + 2);
        aug.validate().unwrap();
    }

    #[test]
    fn bfs_dist_on_diamond() {
        let net = diamond();
        let adj = super::super::Csr::from_edges(net.n, net.edges.iter().map(|e| (e.u, e.v)));
        let d = bfs_dist(net.n, &adj, 0);
        assert_eq!(d, vec![0, 1, 1, 2]);
    }

    #[test]
    fn select_pairs_returns_valid_distinct_pairs() {
        let net = diamond();
        let ps = select_pairs(&net, 2, 8, 42);
        assert!(!ps.is_empty());
        for (a, b) in ps {
            assert_ne!(a, b);
            assert!((a as usize) < net.n && (b as usize) < net.n);
        }
    }
}
