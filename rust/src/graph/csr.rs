//! Plain compressed-sparse-row adjacency (paper Fig. 2b, without residual
//! bookkeeping). Used for BFS traversals (pair selection, global relabel)
//! and as the building block of RCSR / BCSR.

use super::VertexId;

/// CSR over `(u, v)` pairs; payloads (arc ids) can ride along via
/// [`Csr::from_pairs_with`].
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub offsets: Vec<u32>,
    pub cols: Vec<VertexId>,
}

impl Csr {
    /// Build from directed edges.
    pub fn from_edges<I: Iterator<Item = (VertexId, VertexId)>>(n: usize, edges: I) -> Csr {
        let (csr, _) = Csr::from_pairs_with(n, edges.map(|(u, v)| (u, v, 0u32)));
        csr
    }

    /// Build from `(u, v, payload)` triples using counting sort; returns the
    /// CSR and the payload array aligned with `cols`. Stable within a row
    /// (insertion order preserved).
    pub fn from_pairs_with<I: Iterator<Item = (VertexId, VertexId, u32)>>(n: usize, triples: I) -> (Csr, Vec<u32>) {
        let items: Vec<(VertexId, VertexId, u32)> = triples.collect();
        let mut counts = vec![0u32; n + 1];
        for &(u, _, _) in &items {
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let total = offsets[n] as usize;
        let mut cols = vec![0 as VertexId; total];
        let mut payload = vec![0u32; total];
        let mut cursor = offsets.clone();
        for (u, v, p) in items {
            let slot = cursor[u as usize] as usize;
            cols[slot] = v;
            payload[slot] = p;
            cursor[u as usize] += 1;
        }
        (Csr { offsets, cols }, payload)
    }

    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline(always)]
    pub fn range(&self, u: VertexId) -> std::ops::Range<usize> {
        self.offsets[u as usize] as usize..self.offsets[u as usize + 1] as usize
    }

    #[inline(always)]
    pub fn row(&self, u: VertexId) -> &[VertexId] {
        &self.cols[self.range(u)]
    }

    #[inline(always)]
    pub fn degree(&self, u: VertexId) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    pub fn memory_bytes(&self) -> usize {
        self.offsets.len() * 4 + self.cols.len() * 4
    }
}

/// Degree statistics of a CSR — the paper's predictor for when the
/// vertex-centric approach pays off (§4.2: high degree std-dev ⇒ VC wins).
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    pub mean: f64,
    pub std: f64,
    pub max: usize,
    pub min: usize,
}

impl DegreeStats {
    pub fn of(csr: &Csr) -> DegreeStats {
        let n = csr.n();
        if n == 0 {
            return DegreeStats { mean: 0.0, std: 0.0, max: 0, min: 0 };
        }
        let degs: Vec<f64> = (0..n).map(|u| csr.degree(u as VertexId) as f64).collect();
        let s = crate::util::stats::Summary::of(&degs);
        DegreeStats { mean: s.mean, std: s.std, max: s.max as usize, min: s.min as usize }
    }

    /// Coefficient of variation of the degree distribution.
    pub fn cv(&self) -> f64 {
        if self.mean > 0.0 { self.std / self.mean } else { 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_edges(4, vec![(0, 1), (0, 2), (2, 3), (1, 3), (0, 3)].into_iter())
    }

    #[test]
    fn rows_and_degrees() {
        let c = sample();
        assert_eq!(c.n(), 4);
        assert_eq!(c.row(0), &[1, 2, 3]);
        assert_eq!(c.row(1), &[3]);
        assert_eq!(c.row(3), &[] as &[u32]);
        assert_eq!(c.degree(0), 3);
        assert_eq!(c.degree(3), 0);
    }

    #[test]
    fn payload_rides_along() {
        let (c, p) = Csr::from_pairs_with(3, vec![(1, 0, 10), (0, 2, 20), (1, 2, 30)].into_iter());
        assert_eq!(c.row(1), &[0, 2]);
        let r = c.range(1);
        assert_eq!(&p[r], &[10, 30]);
    }

    #[test]
    fn stable_within_row() {
        let (c, p) = Csr::from_pairs_with(2, vec![(0, 1, 1), (0, 1, 2), (0, 1, 3)].into_iter());
        assert_eq!(&p[c.range(0)], &[1, 2, 3]);
    }

    #[test]
    fn empty_graph() {
        let c = Csr::from_edges(3, std::iter::empty());
        assert_eq!(c.n(), 3);
        assert_eq!(c.degree(0), 0);
    }

    #[test]
    fn degree_stats() {
        let c = sample();
        let d = DegreeStats::of(&c);
        assert_eq!(d.max, 3);
        assert_eq!(d.min, 0);
        assert!((d.mean - 1.25).abs() < 1e-12);
        assert!(d.cv() > 0.0);
    }

    #[test]
    fn memory_is_v_plus_e_scale() {
        let c = sample();
        assert_eq!(c.memory_bytes(), (4 + 1) * 4 + 5 * 4);
    }
}
