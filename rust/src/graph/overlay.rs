//! Delta-overlay residual representation for topology-dynamic graphs.
//!
//! [`DeltaRcsr`] wraps a base [`Rcsr`] with a per-row patch/extra overlay so
//! an inserted edge's arc pair becomes scannable immediately (O(1) append)
//! and a deleted edge's arcs disappear from every admissibility scan without
//! rebuilding the CSR. Untouched rows read straight from the base arrays —
//! the overlay costs nothing on rows churn never visits.
//!
//! Row shape (up to four segments, see
//! [`RowSegs`](super::residual::RowSegs)):
//!
//! 1. forward base row *or* its patched copy (when a base forward arc was
//!    deleted from this row),
//! 2. forward extras (arcs of edges inserted since the last merge),
//! 3. reversed base row or its patched copy,
//! 4. reversed extras.
//!
//! The overlay is merged back into a tight base CSR at snapshot/eviction
//! time (or whenever the caller decides churn has accumulated enough):
//! [`DeltaRcsr::merge`] rebuilds the two CSRs from the arc arena, skipping
//! tombstoned (dead) edges, and clears every patch. Arc ids are never
//! renumbered — edge `e` keeps arcs `2e`/`2e+1` for the lifetime of the
//! session, so `rev_arc` stays the O(1) `a ^ 1` pairing and flow state
//! indexed by arc id survives merges untouched.

use super::builder::ArcGraph;
use super::csr::Csr;
use super::rcsr::Rcsr;
use super::residual::{Residual, RowSegs};
use super::VertexId;

/// One vertex's overlay state. `*_patch = Some(row)` replaces the base
/// segment entirely (used when a base arc was deleted); `*_extra` holds
/// arcs appended since the last merge (inserted edges).
#[derive(Debug, Clone, Default)]
struct OvRow {
    fwd_patch: Option<(Vec<u32>, Vec<VertexId>)>,
    fwd_extra: (Vec<u32>, Vec<VertexId>),
    rev_patch: Option<(Vec<u32>, Vec<VertexId>)>,
    rev_extra: (Vec<u32>, Vec<VertexId>),
}

impl OvRow {
    fn is_pristine(&self) -> bool {
        self.fwd_patch.is_none()
            && self.rev_patch.is_none()
            && self.fwd_extra.0.is_empty()
            && self.rev_extra.0.is_empty()
    }
}

/// Base RCSR plus a sparse per-row delta overlay (see module docs).
#[derive(Debug, Clone)]
pub struct DeltaRcsr {
    base: Rcsr,
    /// Overlay row index per vertex; `u32::MAX` = untouched (read base).
    idx: Vec<u32>,
    rows: Vec<OvRow>,
}

const UNTOUCHED: u32 = u32::MAX;

impl DeltaRcsr {
    /// Wrap a freshly built base with an empty overlay.
    pub fn build(g: &ArcGraph) -> DeltaRcsr {
        DeltaRcsr::from_base(Rcsr::build(g))
    }

    /// Build with the arcs of tombstoned edges (`dead[e]`) compacted out
    /// of the base from the start — the dynamic engine's constructor for
    /// evolved edge lists whose capacity-0 slots are tombstones.
    pub fn build_compact(g: &ArcGraph, dead: &[bool]) -> DeltaRcsr {
        DeltaRcsr::from_base(compact_base(g, dead))
    }

    pub fn from_base(base: Rcsr) -> DeltaRcsr {
        let n = base.n();
        DeltaRcsr { base, idx: vec![UNTOUCHED; n], rows: Vec::new() }
    }

    /// True when no row diverges from the base (nothing to merge).
    pub fn is_pristine(&self) -> bool {
        self.rows.iter().all(|r| r.is_pristine())
    }

    /// Number of rows with live overlay state (diagnostics).
    pub fn overlay_rows(&self) -> usize {
        self.rows.iter().filter(|r| !r.is_pristine()).count()
    }

    fn row_mut(&mut self, u: VertexId) -> &mut OvRow {
        let slot = &mut self.idx[u as usize];
        if *slot == UNTOUCHED {
            *slot = self.rows.len() as u32;
            self.rows.push(OvRow::default());
        }
        &mut self.rows[*slot as usize]
    }

    /// Make edge `e = (u → v)`'s arc pair scannable: arc `2e` joins `u`'s
    /// forward extras, arc `2e+1` joins `v`'s reversed extras. O(1).
    pub fn insert_arc_pair(&mut self, e: u32, u: VertexId, v: VertexId) {
        let ov = self.row_mut(u);
        ov.fwd_extra.0.push(2 * e);
        ov.fwd_extra.1.push(v);
        let ov = self.row_mut(v);
        ov.rev_extra.0.push(2 * e + 1);
        ov.rev_extra.1.push(u);
    }

    /// Remove edge `e = (u → v)`'s arc pair from the scannable rows
    /// (tombstone: the arc slots in the arena survive, the representation
    /// just stops yielding them). O(row) worst case when a base row must be
    /// patched for the first time; O(extra) when the edge was itself an
    /// unmerged insert.
    pub fn remove_arc_pair(&mut self, e: u32, u: VertexId, v: VertexId) {
        let a = 2 * e;
        {
            let base = &self.base;
            let fr = base.fwd.range(u);
            let base_fwd: Option<(Vec<u32>, Vec<VertexId>)> = if self.idx[u as usize] == UNTOUCHED
                || self.rows[self.idx[u as usize] as usize].fwd_patch.is_none()
            {
                Some((base.fwd_arcs[fr.clone()].to_vec(), base.fwd.cols[fr].to_vec()))
            } else {
                None
            };
            let ov = self.row_mut(u);
            if let Some(pos) = ov.fwd_extra.0.iter().position(|&x| x == a) {
                ov.fwd_extra.0.swap_remove(pos);
                ov.fwd_extra.1.swap_remove(pos);
            } else {
                let patch = ov.fwd_patch.get_or_insert_with(|| base_fwd.expect("patch exists"));
                let pos = patch.0.iter().position(|&x| x == a).expect("arc present in forward row");
                patch.0.swap_remove(pos);
                patch.1.swap_remove(pos);
            }
        }
        let b = a + 1;
        {
            let base = &self.base;
            let rr = base.rev.range(v);
            let base_rev: Option<(Vec<u32>, Vec<VertexId>)> = if self.idx[v as usize] == UNTOUCHED
                || self.rows[self.idx[v as usize] as usize].rev_patch.is_none()
            {
                Some((base.rev_arcs[rr.clone()].to_vec(), base.rev.cols[rr].to_vec()))
            } else {
                None
            };
            let ov = self.row_mut(v);
            if let Some(pos) = ov.rev_extra.0.iter().position(|&x| x == b) {
                ov.rev_extra.0.swap_remove(pos);
                ov.rev_extra.1.swap_remove(pos);
            } else {
                let patch = ov.rev_patch.get_or_insert_with(|| base_rev.expect("patch exists"));
                let pos = patch.0.iter().position(|&x| x == b).expect("arc present in reversed row");
                patch.0.swap_remove(pos);
                patch.1.swap_remove(pos);
            }
        }
    }

    /// Fold the overlay back into a tight base CSR, dropping the arcs of
    /// tombstoned edges (`dead[e]`) for good. Arc ids are preserved; only
    /// the representation is compacted. Called at snapshot/eviction time.
    pub fn merge(&mut self, g: &ArcGraph, dead: &[bool]) {
        self.base = compact_base(g, dead);
        self.idx.clear();
        self.idx.resize(g.n, UNTOUCHED);
        self.rows.clear();
    }
}

/// Rebuild a tight [`Rcsr`] over the arena, skipping the arcs of
/// tombstoned edges.
fn compact_base(g: &ArcGraph, dead: &[bool]) -> Rcsr {
    let m2 = g.num_arcs();
    let live = |a: u32| !dead[(a / 2) as usize];
    let fwd_iter = (0..m2 as u32)
        .step_by(2)
        .filter(|&a| live(a))
        .map(|a| (g.arc_from[a as usize], g.arc_to[a as usize], a));
    let (fwd, fwd_arcs) = Csr::from_pairs_with(g.n, fwd_iter);
    let rev_iter = (1..m2 as u32)
        .step_by(2)
        .filter(|&a| live(a))
        .map(|a| (g.arc_from[a as usize], g.arc_to[a as usize], a));
    let (rev, rev_arcs) = Csr::from_pairs_with(g.n, rev_iter);
    Rcsr::from_parts(g.n, fwd, fwd_arcs, rev, rev_arcs)
}

impl Residual for DeltaRcsr {
    fn n(&self) -> usize {
        self.base.n()
    }

    fn row(&self, u: VertexId) -> RowSegs<'_> {
        let slot = self.idx[u as usize];
        if slot == UNTOUCHED {
            return self.base.row(u);
        }
        let ov = &self.rows[slot as usize];
        let fr = self.base.fwd.range(u);
        let fwd: (&[u32], &[VertexId]) = match &ov.fwd_patch {
            Some((a, c)) => (a, c),
            None => (&self.base.fwd_arcs[fr.clone()], &self.base.fwd.cols[fr]),
        };
        let rr = self.base.rev.range(u);
        let rev: (&[u32], &[VertexId]) = match &ov.rev_patch {
            Some((a, c)) => (a, c),
            None => (&self.base.rev_arcs[rr.clone()], &self.base.rev.cols[rr]),
        };
        RowSegs::four(
            fwd,
            (&ov.fwd_extra.0, &ov.fwd_extra.1),
            rev,
            (&ov.rev_extra.0, &ov.rev_extra.1),
        )
    }

    #[inline(always)]
    fn rev_arc(&self, a: u32, _from: VertexId, _to: VertexId) -> u32 {
        // O(1): the arena pairing, same as the base RCSR.
        a ^ 1
    }

    fn memory_bytes(&self) -> usize {
        let overlay: usize = self
            .rows
            .iter()
            .map(|r| {
                let patch = |p: &Option<(Vec<u32>, Vec<VertexId>)>| {
                    p.as_ref().map_or(0, |(a, c)| a.len() * 4 + c.len() * 4)
                };
                patch(&r.fwd_patch)
                    + patch(&r.rev_patch)
                    + (r.fwd_extra.0.len() + r.fwd_extra.1.len()) * 4
                    + (r.rev_extra.0.len() + r.rev_extra.1.len()) * 4
            })
            .sum();
        self.base.memory_bytes() + self.idx.len() * 4 + overlay
    }

    fn name(&self) -> &'static str {
        "RCSR+ov"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::FlowNetwork;
    use crate::graph::Edge;

    fn diamond() -> (FlowNetwork, ArcGraph) {
        let net = FlowNetwork::new(
            4,
            0,
            3,
            vec![Edge::new(0, 1, 3), Edge::new(0, 2, 2), Edge::new(1, 3, 2), Edge::new(2, 3, 3)],
            "diamond",
        );
        let g = ArcGraph::build(&net);
        (net, g)
    }

    fn arcs_of(rep: &DeltaRcsr, u: VertexId) -> Vec<(u32, u32)> {
        let mut v: Vec<(u32, u32)> = rep.row(u).iter().collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn pristine_overlay_matches_base_rcsr() {
        let (_, g) = diamond();
        let plain = Rcsr::build(&g);
        let ov = DeltaRcsr::build(&g);
        assert!(ov.is_pristine());
        for u in 0..g.n as u32 {
            let mut a: Vec<(u32, u32)> = plain.row(u).iter().collect();
            let mut b: Vec<(u32, u32)> = ov.row(u).iter().collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "row {u}");
            assert_eq!(ov.degree(u), plain.degree(u));
        }
    }

    #[test]
    fn insert_is_immediately_scannable() {
        let (_, mut g) = diamond();
        let mut rep = DeltaRcsr::build(&g);
        // New edge 4: 1 -> 2, arcs 8 (fwd on row 1) and 9 (rev on row 2).
        g.arc_from.extend([1, 2]);
        g.arc_to.extend([2, 1]);
        g.arc_cap.extend([5, 0]);
        rep.insert_arc_pair(4, 1, 2);
        assert!(arcs_of(&rep, 1).contains(&(8, 2)));
        assert!(arcs_of(&rep, 2).contains(&(9, 1)));
        assert_eq!(rep.degree(1), 4); // arcs 1(rev of 0->1), 4(fwd 1->3), 8
                                      // ... plus nothing else: base row 1 = {1, 4}, extra = {8}.
        assert!(!rep.is_pristine());
        assert_eq!(rep.rev_arc(8, 1, 2), 9);
    }

    #[test]
    fn delete_removes_base_arcs_via_patch() {
        let (_, g) = diamond();
        let mut rep = DeltaRcsr::build(&g);
        // Delete edge 2 (1 -> 3): arc 4 leaves row 1, arc 5 leaves row 3.
        rep.remove_arc_pair(2, 1, 3);
        assert!(!arcs_of(&rep, 1).contains(&(4, 3)));
        assert!(!arcs_of(&rep, 3).contains(&(5, 1)));
        // Unrelated arcs survive.
        assert!(arcs_of(&rep, 1).contains(&(1, 0)));
        assert!(arcs_of(&rep, 3).contains(&(7, 2)));
        assert_eq!(rep.degree(1), 1);
    }

    #[test]
    fn delete_of_unmerged_insert_cancels_in_overlay() {
        let (_, mut g) = diamond();
        let mut rep = DeltaRcsr::build(&g);
        g.arc_from.extend([1, 2]);
        g.arc_to.extend([2, 1]);
        g.arc_cap.extend([5, 0]);
        rep.insert_arc_pair(4, 1, 2);
        rep.remove_arc_pair(4, 1, 2);
        assert!(!arcs_of(&rep, 1).contains(&(8, 2)));
        assert!(!arcs_of(&rep, 2).contains(&(9, 1)));
        assert_eq!(rep.degree(1), 3);
    }

    #[test]
    fn merge_compacts_dead_edges_and_clears_overlay() {
        let (_, mut g) = diamond();
        let mut rep = DeltaRcsr::build(&g);
        // Insert edge 4 (1 -> 2), delete edge 0 (0 -> 1).
        g.arc_from.extend([1, 2]);
        g.arc_to.extend([2, 1]);
        g.arc_cap.extend([5, 0]);
        rep.insert_arc_pair(4, 1, 2);
        rep.remove_arc_pair(0, 0, 1);
        let mut dead = vec![false; 5];
        dead[0] = true;
        let before: Vec<Vec<(u32, u32)>> = (0..4).map(|u| arcs_of(&rep, u)).collect();
        rep.merge(&g, &dead);
        assert!(rep.is_pristine());
        // Same residual arcs visible before and after the merge.
        for u in 0..4u32 {
            assert_eq!(arcs_of(&rep, u), before[u as usize], "row {u}");
        }
        // Dead arcs are gone from the representation for good.
        assert!(!arcs_of(&rep, 0).contains(&(0, 1)));
        assert!(!arcs_of(&rep, 1).contains(&(1, 0)));
        // Live arc ids unchanged (edge 4 still arcs 8/9).
        assert!(arcs_of(&rep, 1).contains(&(8, 2)));
        assert!(arcs_of(&rep, 2).contains(&(9, 1)));
    }

    #[test]
    fn every_arc_appears_exactly_once_under_churn() {
        let (_, mut g) = diamond();
        let mut rep = DeltaRcsr::build(&g);
        g.arc_from.extend([1, 2, 3, 0]);
        g.arc_to.extend([2, 1, 0, 3]);
        g.arc_cap.extend([5, 0, 2, 0]);
        rep.insert_arc_pair(4, 1, 2);
        rep.insert_arc_pair(5, 3, 0);
        rep.remove_arc_pair(1, 0, 2);
        let mut seen = std::collections::HashMap::new();
        for u in 0..4u32 {
            for (a, v) in rep.row(u).iter() {
                *seen.entry(a).or_insert(0u32) += 1;
                assert_eq!(g.arc_from[a as usize], u);
                assert_eq!(g.arc_to[a as usize], v);
            }
        }
        assert!(seen.values().all(|&c| c == 1));
        // 6 live edges x 2 arcs (edges 0,2,3,4,5 live; edge 1 deleted).
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn memory_accounts_for_overlay() {
        let (_, mut g) = diamond();
        let mut rep = DeltaRcsr::build(&g);
        let base_bytes = rep.memory_bytes();
        g.arc_from.extend([1, 2]);
        g.arc_to.extend([2, 1]);
        g.arc_cap.extend([5, 0]);
        rep.insert_arc_pair(4, 1, 2);
        assert!(rep.memory_bytes() > base_bytes);
    }
}
