//! SNAP edge-list format parser (`# comment` header lines, then
//! whitespace-separated `u v` pairs). The paper sets all SNAP capacities
//! to 1 (§4.1, Table 1 caption); we do the same, relabeling arbitrary
//! vertex ids to a dense `0..n` range.

use super::builder::FlowNetwork;
use super::{Edge, VertexId};
use std::collections::HashMap;

/// Parse SNAP edge-list text into a unit-capacity network. `s`/`t` default
/// to the first/last relabeled vertices; callers normally re-select
/// terminals with `builder::select_pairs` + `add_super_terminals`.
pub fn parse(text: &str) -> Result<FlowNetwork, String> {
    let mut remap: HashMap<u64, VertexId> = HashMap::new();
    let mut edges: Vec<Edge> = Vec::new();
    let intern = |remap: &mut HashMap<u64, VertexId>, raw: u64| -> VertexId {
        let next = remap.len() as VertexId;
        *remap.entry(raw).or_insert(next)
    };
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| format!("line {}: bad edge", lineno + 1))?;
        let v: u64 = it
            .next()
            .and_then(|x| x.parse().ok())
            .ok_or_else(|| format!("line {}: bad edge", lineno + 1))?;
        let u = intern(&mut remap, u);
        let v = intern(&mut remap, v);
        if u != v {
            edges.push(Edge::new(u, v, 1));
        }
    }
    let n = remap.len();
    if n < 2 {
        return Err("graph has fewer than 2 vertices".into());
    }
    Ok(FlowNetwork { n, s: 0, t: (n - 1) as VertexId, edges, name: "snap".into() }.normalized())
}

/// Read a SNAP file.
pub fn read(path: &str) -> Result<FlowNetwork, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_comments_and_remap() {
        let net = parse("# Directed graph\n# Nodes: 3 Edges: 3\n10 20\n20 30\n30 10\n").unwrap();
        assert_eq!(net.n, 3);
        assert_eq!(net.m(), 3);
        assert!(net.edges.iter().all(|e| e.cap == 1));
    }

    #[test]
    fn drops_self_loops_and_dups() {
        let net = parse("1 1\n1 2\n1 2\n2 1\n").unwrap();
        assert_eq!(net.n, 2);
        assert_eq!(net.m(), 2); // 1->2 (deduped) and 2->1
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("not numbers\n").is_err());
        assert!(parse("# only comments\n").is_err());
    }

    #[test]
    fn tabs_and_spaces() {
        let net = parse("0\t1\n1 2\n").unwrap();
        assert_eq!(net.n, 3);
        assert_eq!(net.m(), 2);
    }
}
