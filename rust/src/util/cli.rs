//! Tiny argv parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, repeated options, and
//! positional arguments, which is all the `wbpr` launcher needs.

use std::collections::BTreeMap;

/// Parsed command line: positionals + options (last-wins plus full history).
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (normally `std::env::args().skip(1)`).
    ///
    /// Any `--name` followed by a token that does not start with `--` is an
    /// option with a value, unless `name` is listed in `bool_flags`.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I, bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.opts.entry(name.to_string()).or_default().push(toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Last value of `--name`.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeated `--name`.
    pub fn opt_all(&self, name: &str) -> &[String] {
        self.opts.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Was `--name` given as a flag?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: '{v}' is not a number")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose", "quiet"])
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("maxflow --graph genrmf --seed 7 input.dimacs");
        assert_eq!(a.positional, vec!["maxflow", "input.dimacs"]);
        assert_eq!(a.opt("graph"), Some("genrmf"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn equals_form() {
        let a = parse("--k=v --n=3");
        assert_eq!(a.opt("k"), Some("v"));
        assert_eq!(a.opt_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn bool_flags_consume_nothing() {
        let a = parse("--verbose run");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn repeated_options_collect() {
        let a = parse("--set a.b=1 --set c.d=2");
        assert_eq!(a.opt_all("set"), &["a.b=1".to_string(), "c.d=2".to_string()]);
        assert_eq!(a.opt("set"), Some("c.d=2"));
    }

    #[test]
    fn trailing_option_without_value_is_flag() {
        let a = parse("--maybe");
        assert!(a.flag("maybe"));
        assert_eq!(a.opt("maybe"), None);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--n notanum");
        assert!(a.opt_usize("n", 0).is_err());
    }
}
