//! In-repo property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Rng`]; the harness runs it for many
//! seeded cases and, on failure, retries the failing case with a fresh seed
//! derived deterministically so failures are reproducible from the printed
//! seed. A lightweight "shrink" is provided for integer size parameters:
//! generators draw sizes through [`Gen::size`], and on failure the harness
//! re-runs with progressively smaller size budgets to find a small
//! counterexample.

use super::rng::Rng;

/// Generation context handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Size budget in [0, 1]; generators should scale their dimensions by it.
    pub budget: f64,
}

impl Gen {
    /// A size in `[lo, hi]`, scaled by the current shrink budget.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.budget).round() as usize;
        lo + if span == 0 { 0 } else { self.rng.index(span + 1) }
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct PropReport {
    pub cases: u32,
    pub failure: Option<String>,
}

/// Run `prop` for `cases` random cases. On the first failure, attempt to
/// shrink by re-running the same seed with smaller size budgets, then panic
/// with the smallest failing description.
///
/// `prop` returns `Ok(())` on success or `Err(description)` on failure and
/// may also panic (panics are treated as failures with the panic message).
pub fn check<F>(name: &str, cases: u32, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let report = check_quiet(cases, base_seed, &prop);
    if let Some(msg) = report.failure {
        panic!("property '{name}' failed: {msg}");
    }
}

/// Non-panicking variant (used by the harness's own tests).
pub fn check_quiet<F>(cases: u32, base_seed: u64, prop: &F) -> PropReport
where
    F: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        if let Err(msg) = run_case(prop, seed, 1.0) {
            // Shrink: smaller budgets, same seed.
            let mut best = (1.0, msg);
            for &budget in &[0.5, 0.25, 0.1, 0.05, 0.0] {
                if let Err(m) = run_case(prop, seed, budget) {
                    best = (budget, m);
                } else {
                    break;
                }
            }
            let (budget, msg) = best;
            return PropReport {
                cases: case + 1,
                failure: Some(format!("case {case} seed {seed:#x} budget {budget}: {msg}")),
            };
        }
    }
    PropReport { cases, failure: None }
}

fn run_case<F>(prop: &F, seed: u64, budget: f64) -> Result<(), String>
where
    F: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen { rng: Rng::new(seed), budget };
        prop(&mut g)
    });
    match result {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic".to_string());
            Err(format!("panicked: {msg}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, 1, |g| {
            let a = g.rng.range_i64(-1000, 1000);
            let b = g.rng.range_i64(-1000, 1000);
            if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let r = check_quiet(100, 7, &|g: &mut Gen| {
            let n = g.size(0, 1000);
            if n > 900 { Err(format!("n={n} too big")) } else { Ok(()) }
        });
        let msg = r.failure.expect("should fail eventually");
        assert!(msg.contains("budget"), "message: {msg}");
    }

    #[test]
    fn panics_are_captured() {
        let r = check_quiet(10, 3, &|g: &mut Gen| {
            if g.rng.chance(1.0) {
                panic!("boom");
            }
            Ok(())
        });
        assert!(r.failure.unwrap().contains("boom"));
    }

    #[test]
    fn size_respects_bounds_and_budget() {
        let mut g = Gen { rng: Rng::new(1), budget: 0.0 };
        for _ in 0..50 {
            assert_eq!(g.size(3, 100), 3);
        }
        let mut g = Gen { rng: Rng::new(2), budget: 1.0 };
        for _ in 0..200 {
            let s = g.size(3, 10);
            assert!((3..=10).contains(&s));
        }
    }
}
