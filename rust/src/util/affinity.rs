//! Thread-to-core pinning and NUMA topology discovery, dependency-free.
//!
//! The build is offline (no `libc`/`core_affinity` crates), so the one
//! kernel interface this needs — `sched_setaffinity(2)` — is issued as a
//! raw syscall via inline asm on Linux x86_64/aarch64, and degrades to a
//! no-op "pinning unsupported" answer elsewhere. Topology comes from
//! sysfs (`/sys/devices/system/node/node*/cpulist`), falling back to one
//! synthetic node covering the machine's available parallelism when the
//! NUMA tree is absent (containers, non-Linux).
//!
//! Used by `maxflow::pool::WorkerPool::with_config` to pin each worker at
//! spawn (`--pin-cores` / `--numa-interleave`); see DESIGN.md §3d.

/// Parse a kernel-style cpu list: `"0,2,4-7"` → `[0, 2, 4, 5, 6, 7]`.
/// The same syntax serves the `--pin-cores` flag and sysfs `cpulist`
/// files. Empty input is an error (an empty pin list means "don't pin",
/// which callers spell by omitting the flag).
pub fn parse_core_list(s: &str) -> Result<Vec<usize>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().map_err(|_| format!("bad core range '{part}'"))?;
                let hi: usize = hi.trim().parse().map_err(|_| format!("bad core range '{part}'"))?;
                if hi < lo {
                    return Err(format!("bad core range '{part}' (end before start)"));
                }
                out.extend(lo..=hi);
            }
            None => out.push(part.parse().map_err(|_| format!("bad core id '{part}'"))?),
        }
    }
    if out.is_empty() {
        return Err(format!("empty core list '{s}'"));
    }
    Ok(out)
}

/// Cores per NUMA node, from sysfs. Always returns at least one node; a
/// machine without an exposed NUMA tree (or a non-Linux host) reports a
/// single node holding cores `0..available_parallelism`.
pub fn numa_node_cpus() -> Vec<Vec<usize>> {
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir("/sys/devices/system/node") {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(idx) = name.strip_prefix("node").and_then(|d| d.parse::<usize>().ok()) else {
                continue;
            };
            let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                continue;
            };
            if let Ok(cpus) = parse_core_list(list.trim()) {
                nodes.push((idx, cpus));
            }
        }
    }
    nodes.sort_by_key(|(idx, _)| *idx);
    if nodes.is_empty() {
        let p = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        return vec![(0..p).collect()];
    }
    nodes.into_iter().map(|(_, cpus)| cpus).collect()
}

/// Round-robin `workers` across NUMA nodes: worker `w` goes to node
/// `w % nodes`, walking each node's core list in order (wrapping when
/// oversubscribed). On a single-node machine this degrades to sequential
/// core assignment, which is still a win over OS scatter (stable L1/L2
/// affinity across launches).
pub fn interleave_across_nodes(workers: usize) -> Vec<usize> {
    let nodes = numa_node_cpus();
    let nodes: Vec<&Vec<usize>> = nodes.iter().filter(|n| !n.is_empty()).collect();
    if nodes.is_empty() {
        return (0..workers).collect();
    }
    (0..workers)
        .map(|w| {
            let node = nodes[w % nodes.len()];
            node[(w / nodes.len()) % node.len()]
        })
        .collect()
}

/// Pin the calling thread to a single `core`. Returns `false` when the
/// kernel rejects the mask (offline/nonexistent core) or the platform
/// has no pinning support — callers treat pinning as best-effort and
/// report the count that stuck (`WorkerPool::pinned_workers`).
pub fn pin_current_thread_to(core: usize) -> bool {
    let mut mask = vec![0u64; core / 64 + 1];
    mask[core / 64] = 1u64 << (core % 64);
    sched_setaffinity_self(&mask) == 0
}

/// `sched_setaffinity(0, ...)` — pid 0 is the calling thread. Returns 0
/// on success, a negative errno otherwise.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_self(mask: &[u64]) -> i64 {
    let ret: i64;
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret, // __NR_sched_setaffinity
            in("rdi") 0i64,
            in("rsi") mask.len() * 8,
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_self(mask: &[u64]) -> i64 {
    let ret: i64;
    unsafe {
        std::arch::asm!(
            "svc #0",
            in("x8") 122i64, // __NR_sched_setaffinity
            inlateout("x0") 0i64 => ret,
            in("x1") mask.len() * 8,
            in("x2") mask.as_ptr(),
            options(nostack)
        );
    }
    ret
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn sched_setaffinity_self(_mask: &[u64]) -> i64 {
    -1 // pinning unsupported on this platform; callers degrade gracefully
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_list_round_trips() {
        assert_eq!(parse_core_list("0,2,4-7").unwrap(), vec![0, 2, 4, 5, 6, 7]);
        assert_eq!(parse_core_list("3").unwrap(), vec![3]);
        assert_eq!(parse_core_list("0-3,8-11").unwrap(), vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(parse_core_list(" 1 , 2 ").unwrap(), vec![1, 2]);
        assert!(parse_core_list("").is_err());
        assert!(parse_core_list("7-3").is_err());
        assert!(parse_core_list("a-b").is_err());
    }

    #[test]
    fn topology_always_reports_a_node() {
        let nodes = numa_node_cpus();
        assert!(!nodes.is_empty());
        assert!(nodes.iter().any(|n| !n.is_empty()));
    }

    #[test]
    fn interleave_covers_every_worker() {
        for workers in [1usize, 3, 8, 19] {
            let placement = interleave_across_nodes(workers);
            assert_eq!(placement.len(), workers);
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn pinning_to_core_zero_succeeds_and_bogus_core_fails() {
        // Core 0 exists on every Linux machine this repo targets.
        assert!(pin_current_thread_to(0), "pin to core 0");
        // An absurd core id must be rejected (EINVAL), not crash.
        assert!(!pin_current_thread_to(10_000));
        // Re-widen so the test thread doesn't stay pinned for the rest of
        // the harness: pin to every core of node 0.
        let all = numa_node_cpus().concat();
        let mut mask = vec![0u64; all.iter().max().unwrap() / 64 + 1];
        for c in all {
            mask[c / 64] |= 1 << (c % 64);
        }
        assert_eq!(sched_setaffinity_self(&mask), 0);
    }
}
