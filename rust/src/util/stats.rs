//! Descriptive statistics used by the SIMT workload analysis (Figure 3),
//! the benchmark report printer, and the coordinator metrics.

/// Summary statistics over a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// Coefficient of variation (std / mean) — the paper's Figure 3 metric
    /// for workload imbalance across warps, after mean-normalization.
    pub cv: f64,
}

impl Summary {
    /// Compute a summary; returns an all-zero summary for an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0, cv: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let std = var.sqrt();
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = (p * (n - 1) as f64).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std,
            min: sorted[0],
            max: sorted[n - 1],
            p50: q(0.50),
            p90: q(0.90),
            p99: q(0.99),
            cv: if mean.abs() > 1e-300 { std / mean } else { 0.0 },
        }
    }
}

/// A fixed-bucket histogram for latency-style metrics (exponential buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), ascending; final bucket is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
}

impl Histogram {
    /// Exponential buckets: `base * growth^i` for i in 0..buckets.
    pub fn exponential(base: f64, growth: f64, buckets: usize) -> Histogram {
        assert!(base > 0.0 && growth > 1.0 && buckets >= 1);
        let bounds: Vec<f64> = (0..buckets).map(|i| base * growth.powi(i as i32)).collect();
        let counts = vec![0; buckets + 1];
        Histogram { bounds, counts, sum: 0.0, n: 0 }
    }

    /// The preset the coordinator uses for per-engine solve latency:
    /// 10 µs to ~84 s in 24 doubling buckets. At growth 2.0 a reported
    /// quantile (bucket upper bound) overstates the true order statistic
    /// by at most 2× — adequate for the p50/p99/p999 the serving surface
    /// exports, at 200 bytes per engine.
    pub fn latency() -> Histogram {
        Histogram::exponential(0.01, 2.0, 24)
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v < b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of every recorded value (the Prometheus `_sum` series).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    /// Approximate quantile from bucket boundaries (upper bound of the bucket
    /// containing the q-quantile observation).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
            }
        }
        f64::INFINITY
    }
}

/// Online mean/std (Welford) — used in hot loops where we cannot afford to
/// buffer every sample.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n == 0 { 0.0 } else { (self.m2 / self.n as f64).sqrt() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.cv, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99);
        assert!((s.p50 - 499.5).abs() < 1.0);
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::exponential(1.0, 2.0, 10);
        for i in 0..100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 49.5).abs() < 1e-9);
        assert!(h.quantile(0.5) >= 32.0 && h.quantile(0.5) <= 64.0);
        assert!(h.quantile(0.99) >= 64.0);
    }

    #[test]
    fn latency_preset_quantiles_are_ordered_and_bracket_the_tail() {
        let mut h = Histogram::latency();
        // 998 fast solves at ~1ms, two slow outliers at ~500ms: p50/p99
        // stay in the fast band, p999 must reach the outliers' bucket
        // (ceil(0.999 * 1000) = 999 > 998 fast observations).
        for _ in 0..998 {
            h.record(1.0);
        }
        h.record(500.0);
        h.record(500.0);
        let (p50, p99, p999) = (h.quantile(0.5), h.quantile(0.99), h.quantile(0.999));
        assert!(p50 <= p99 && p99 <= p999, "quantiles must be monotone");
        assert!(p50 <= 2.0, "p50 stays in the 1ms band, got {p50}");
        assert!(p99 <= 2.0, "p99 stays in the 1ms band, got {p99}");
        assert!(p999 >= 500.0, "p999 must see the outliers, got {p999}");
        assert!(p999.is_finite(), "500ms fits the 24-bucket range");
        assert!((h.sum() - (998.0 + 1000.0)).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..500).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
    }
}
