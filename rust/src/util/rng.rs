//! Deterministic pseudo-random number generation (splitmix64 + xoshiro256**).
//!
//! Every stochastic component in the repo (graph generators, property tests,
//! workload drivers) takes an explicit seed so experiments are reproducible
//! bit-for-bit, matching the paper's fixed source/sink pair lists.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // splitmix64 to spread the seed over the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's nearly-divisionless method.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                m = (self.next_u64() as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from an (approximate) Zipf distribution over `{0, .., n-1}`
    /// with exponent `s`, via the continuous inverse-CDF of x^-s on [1, n].
    /// Exact Zipf is unnecessary here: this is used only to generate the
    /// degree *skew* of synthetic power-law graphs.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        if n == 1 {
            return 0;
        }
        let nf = n as f64;
        let u = self.f64();
        let x = if (s - 1.0).abs() < 1e-9 {
            nf.powf(u)
        } else {
            ((nf.powf(1.0 - s) - 1.0) * u + 1.0).powf(1.0 / (1.0 - s))
        };
        let k = x.floor().max(1.0).min(nf);
        (k as usize) - 1
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            let k = r.zipf(100, 1.2);
            assert!(k < 100);
            counts[k] += 1;
        }
        // Rank 0 should dominate rank 50 heavily under s=1.2.
        assert!(counts[0] > 10 * counts[50].max(1));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(17);
        let mut c = a.fork();
        let av: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let cv: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_ne!(av, cv);
    }
}
