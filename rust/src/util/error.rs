//! An `anyhow`-compatible error shim for the `device` feature.
//!
//! The real PJRT client (`runtime/client.rs`) was written against
//! `anyhow::{anyhow, Context, Result}` from the vendored closure. Offline
//! builds don't have that closure, but CI still type-checks the device
//! path (`cargo check --features device`), so this module reimplements the
//! three names the device code uses with identical call-site syntax. When
//! the `xla` closure is vendored, swapping the `use` lines in
//! `runtime/client.rs` / `coordinator/device.rs` back to the real crates
//! is the only change needed.

use std::fmt;

/// A string-backed error with `anyhow`-style context chaining.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` lowers to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    fn push_context(mut self, c: impl fmt::Display) -> Error {
        self.context.push(c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first, like anyhow's single-line rendering.
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Drop-in for `anyhow::Context` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).push_context(c))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
}

/// Drop-in for `anyhow::anyhow!`: a format string (inline captures work,
/// they lower to `format!`) or any single displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($msg:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($msg, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms_and_display() {
        let world = "pjrt";
        let a = anyhow!("plain");
        let b = anyhow!("fmt {world} {}", 7);
        let c = anyhow!(String::from("owned"));
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "fmt pjrt 7");
        assert_eq!(c.to_string(), "owned");
        assert_eq!(format!("{b:?}"), "fmt pjrt 7");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let err: std::result::Result<u32, String> = Err("inner".into());
        assert_eq!(err.context("outer").unwrap_err().to_string(), "outer: inner");
        let ok: std::result::Result<u32, String> = Ok(3);
        assert_eq!(ok.context("ignored").unwrap(), 3);
    }
}
