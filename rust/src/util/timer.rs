//! Wall-clock timing helpers for the hand-rolled benchmark harness
//! (criterion is unavailable offline).

use std::time::{Duration, Instant};

/// A simple scope timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Benchmark result for one measured routine.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

/// Run `f` with warmup then `iters` measured repetitions; report stats.
///
/// This is the repo's stand-in for criterion: fixed iteration counts keep
/// total bench time bounded and the output format is one row per routine,
/// which the table-regeneration benches aggregate into paper-style tables.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let s = crate::util::stats::Summary::of(&samples);
    BenchResult { name: name.to_string(), iters: iters.max(1), mean_ms: s.mean, std_ms: s.std, min_ms: s.min }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.ms() >= 4.0);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0u32;
        let r = bench("noop", 2, 10, || {
            count += 1;
        });
        assert_eq!(count, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_ms >= 0.0);
    }
}
