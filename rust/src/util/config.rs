//! INI-style configuration files + key=value overrides.
//!
//! The launcher (`wbpr` CLI) accepts `--config path.ini` plus repeated
//! `--set section.key=value` overrides, mirroring the config systems of
//! larger frameworks (MaxText/Megatron-style) without external deps.
//!
//! Format:
//! ```text
//! # comment
//! [engine]
//! kind = vc            ; inline comments allowed after ';' or '#'
//! representation = bcsr
//! cycles_per_launch = 128
//! ```

use std::collections::BTreeMap;

/// Parsed configuration: section -> key -> value (strings; typed getters).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse from INI text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::new();
        let mut section = String::from("global");
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            cfg.set(&section, k.trim(), v.trim());
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    /// Set a value.
    pub fn set(&mut self, section: &str, key: &str, value: &str) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.to_string());
    }

    /// Apply a `section.key=value` override string.
    pub fn apply_override(&mut self, spec: &str) -> Result<(), String> {
        let (path, value) = spec.split_once('=').ok_or_else(|| format!("override '{spec}': expected section.key=value"))?;
        let (section, key) = path.split_once('.').ok_or_else(|| format!("override '{spec}': expected section.key=value"))?;
        self.set(section.trim(), key.trim(), value.trim());
        Ok(())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section).and_then(|s| s.get(key)).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> Result<usize, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("{section}.{key}: '{v}' is not an integer")),
        }
    }

    pub fn get_u64(&self, section: &str, key: &str, default: u64) -> Result<u64, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("{section}.{key}: '{v}' is not an integer")),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> Result<f64, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("{section}.{key}: '{v}' is not a number")),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool, String> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") | Some("on") => Ok(true),
            Some("false") | Some("0") | Some("no") | Some("off") => Ok(false),
            Some(v) => Err(format!("{section}.{key}: '{v}' is not a bool")),
        }
    }

    /// All keys of one section (for diagnostics).
    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, String>> {
        self.sections.get(name)
    }
}

fn strip_comment(line: &str) -> &str {
    // Comments start at '#' or ';' (not inside values — our values never
    // legitimately contain these characters).
    match line.find(|c| c == '#' || c == ';') {
        Some(i) => &line[..i],
        None => line,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\n# top comment\n[engine]\nkind = vc ; inline\nrepresentation = bcsr\ncycles_per_launch = 128\n\n[simt]\nwarps = 82\nenable = true\nfrac = 0.5\n";

    #[test]
    fn parses_sections_and_values() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("engine", "kind"), Some("vc"));
        assert_eq!(c.get("engine", "representation"), Some("bcsr"));
        assert_eq!(c.get_usize("engine", "cycles_per_launch", 0).unwrap(), 128);
        assert_eq!(c.get_usize("simt", "warps", 0).unwrap(), 82);
        assert!(c.get_bool("simt", "enable", false).unwrap());
        assert_eq!(c.get_f64("simt", "frac", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn defaults_apply_when_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_usize("x", "y", 7).unwrap(), 7);
        assert!(!c.get_bool("x", "y", false).unwrap());
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.apply_override("engine.kind=tc").unwrap();
        assert_eq!(c.get("engine", "kind"), Some("tc"));
        assert!(c.apply_override("malformed").is_err());
        assert!(c.apply_override("nosection=1").is_err());
    }

    #[test]
    fn bad_values_error() {
        let c = Config::parse("[a]\nx = notanum\n").unwrap();
        assert!(c.get_usize("a", "x", 0).is_err());
        assert!(c.get_bool("a", "x", false).is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("keywithoutvalue\n").is_err());
    }
}
