//! Dependency-light utilities.
//!
//! The build environment is offline and only vendors the `xla` crate's
//! dependency closure, so the conveniences a project would normally pull from
//! crates.io (rand, clap, serde_json, criterion, proptest) are implemented
//! here at the scale this repo needs them.

pub mod affinity;
pub mod cli;
pub mod config;
pub mod error;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;
