//! Minimal leveled logger (env-controlled via `WBPR_LOG=debug|info|warn|error`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn init_from_env() -> u8 {
    let lvl = match std::env::var("WBPR_LOG").ok().as_deref() {
        Some("debug") => Level::Debug,
        Some("warn") => Level::Warn,
        Some("error") => Level::Error,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current threshold level.
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == 255 { init_from_env() } else { l }
}

/// Override the level programmatically (tests, CLI `--quiet`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Emit one log line if `lvl` clears the threshold.
pub fn log(lvl: Level, target: &str, msg: &str) {
    if (lvl as u8) < level() {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match lvl {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{:>10}.{:03} {} {}] {}", t.as_secs(), t.subsec_millis(), tag, target, msg);
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, $target, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!((Level::Debug as u8) < (Level::Info as u8));
        assert!((Level::Info as u8) < (Level::Warn as u8));
        assert!((Level::Warn as u8) < (Level::Error as u8));
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Error);
        assert_eq!(level(), Level::Error as u8);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info as u8);
    }
}
