//! Minimal leveled logger (env-controlled via `WBPR_LOG=debug|info|warn|error`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

/// Map a `WBPR_LOG` value to a level. The second element is true when the
/// value was unrecognised and the Info fallback was applied — surfaced as
/// a warning so a typo (`WBPR_LOG=dbug`) doesn't silently run at Info.
fn parse_level(val: Option<&str>) -> (Level, bool) {
    match val {
        Some("debug") => (Level::Debug, false),
        Some("info") | None => (Level::Info, false),
        Some("warn") => (Level::Warn, false),
        Some("error") => (Level::Error, false),
        Some(_) => (Level::Info, true),
    }
}

fn init_from_env() -> u8 {
    let raw = std::env::var("WBPR_LOG").ok();
    let (level, unrecognised) = parse_level(raw.as_deref());
    let lvl = level as u8;
    // Store before warning: `log` below re-reads the level, and must not
    // re-enter this initialiser.
    LEVEL.store(lvl, Ordering::Relaxed);
    if unrecognised {
        log(
            Level::Warn,
            "log",
            &format!(
                "unrecognised WBPR_LOG value {:?} (expected debug|info|warn|error); using info",
                raw.unwrap_or_default()
            ),
        );
    }
    lvl
}

/// Current threshold level.
pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == 255 { init_from_env() } else { l }
}

/// Override the level programmatically (tests, CLI `--quiet`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Emit one log line if `lvl` clears the threshold.
pub fn log(lvl: Level, target: &str, msg: &str) {
    if (lvl as u8) < level() {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match lvl {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{:>10}.{:03} {} {}] {}", t.as_secs(), t.subsec_millis(), tag, target, msg);
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, $target, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!((Level::Debug as u8) < (Level::Info as u8));
        assert!((Level::Info as u8) < (Level::Warn as u8));
        assert!((Level::Warn as u8) < (Level::Error as u8));
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Error);
        assert_eq!(level(), Level::Error as u8);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info as u8);
    }

    #[test]
    fn parse_level_fallback_warns_only_on_unrecognised() {
        // Pure-function test: no env mutation, so no race with parallel
        // tests that read WBPR_LOG.
        assert_eq!(parse_level(Some("debug")), (Level::Debug, false));
        assert_eq!(parse_level(Some("info")), (Level::Info, false));
        assert_eq!(parse_level(Some("warn")), (Level::Warn, false));
        assert_eq!(parse_level(Some("error")), (Level::Error, false));
        assert_eq!(parse_level(None), (Level::Info, false), "unset env is the quiet default");
        assert_eq!(parse_level(Some("dbug")), (Level::Info, true), "typo falls back loudly");
        assert_eq!(parse_level(Some("")), (Level::Info, true));
    }
}
