//! `cargo bench --bench fig3_workload` — regenerates the paper's Figure 3
//! (per-warp workload distribution, TC vs VC on RCSR, across the
//! bipartite suite). Scale with WBPR_BENCH_SCALE=smoke.

use wbpr::bench::{fig3, Scale};

fn main() {
    let scale = match std::env::var("WBPR_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::Smoke,
        _ => Scale::Full,
    };
    eprintln!("running Figure 3 suite at {scale:?} scale ...");
    let t = std::time::Instant::now();
    let rows = fig3::run(scale);
    println!("# Figure 3 — per-warp workload distribution (TC vs VC, RCSR)\n");
    println!("{}", fig3::render(&rows));
    eprintln!("fig3 done in {:.1}s", t.elapsed().as_secs_f64());
}
