//! `cargo bench --bench table1_maxflow` — regenerates the paper's Table 1
//! (max-flow execution times, TC/VC × RCSR/BCSR across the 13-graph
//! suite): simulated GPU ms (the paper's metric — shape target) plus the
//! native engines' measured wall-clock. Scale with WBPR_BENCH_SCALE=smoke.

use wbpr::bench::{table1, Scale};
use wbpr::maxflow::SolveOptions;

fn main() {
    let scale = match std::env::var("WBPR_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::Smoke,
        _ => Scale::Full,
    };
    let opts = SolveOptions { cycles_per_launch: 256, ..Default::default() };
    eprintln!("running Table 1 suite at {scale:?} scale ...");
    let t = std::time::Instant::now();
    let rows = table1::run(scale, &opts);
    println!("# Table 1 — max-flow execution time (scaled analogs)\n");
    println!("{}", table1::render(&rows));
    eprintln!("table1 done in {:.1}s", t.elapsed().as_secs_f64());
}
