//! `cargo bench --bench table3_dynamic` — the dynamic-workload table:
//! incremental repair (`dynamic::DynamicFlow`) vs from-scratch VC+BCSR
//! and Dinic re-solves across streams of 1%-of-|E| capacity-update
//! batches, using the shared `SolveStats` push/relabel counters as the
//! work metric. Scale with WBPR_BENCH_SCALE=smoke.

use wbpr::bench::{table3, Scale};
use wbpr::maxflow::SolveOptions;

fn main() {
    let scale = match std::env::var("WBPR_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::Smoke,
        _ => Scale::Full,
    };
    let opts = SolveOptions { cycles_per_launch: 256, ..Default::default() };
    eprintln!("running Table 3 dynamic suite at {scale:?} scale ...");
    let t = std::time::Instant::now();
    let rows = table3::run(scale, &opts);
    println!("# Table 3 — incremental repair vs from-scratch (streaming capacity updates)\n");
    println!("{}", table3::render(&rows));
    eprintln!("table3 done in {:.1}s", t.elapsed().as_secs_f64());
}
