//! `cargo bench --bench table2_matching` — regenerates the paper's Table 2
//! (bipartite matching through the flow pipeline, B0–B12 analogs):
//! matching sizes (vs Hopcroft–Karp), simulated GPU ms per configuration,
//! and native wall-clock. Scale with WBPR_BENCH_SCALE=smoke.

use wbpr::bench::{table2, Scale};
use wbpr::maxflow::SolveOptions;

fn main() {
    let scale = match std::env::var("WBPR_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::Smoke,
        _ => Scale::Full,
    };
    let opts = SolveOptions { cycles_per_launch: 256, ..Default::default() };
    eprintln!("running Table 2 suite at {scale:?} scale ...");
    let t = std::time::Instant::now();
    let rows = table2::run(scale, &opts);
    println!("# Table 2 — bipartite matching execution time (scaled analogs)\n");
    println!("{}", table2::render(&rows));
    eprintln!("table2 done in {:.1}s", t.elapsed().as_secs_f64());
}
