//! `cargo bench --bench kernel_micro` — microbenchmarks of the hot paths:
//!
//! * the lock-free local operation (`discharge_once`) per representation,
//! * the admissibility scan kernels (scalar vs lane-chunked) across the
//!   degree classes the cooperative hub path serves (run it twice, with
//!   and without `--features simd`, to compare the 8- and 16-lane
//!   windows),
//! * the global-relabel BFS (sequential vs the parallel level-synchronous
//!   pass vs the forced-direction ablations) per graph class,
//! * the PJRT device launch (K cycles of the AOT executable) per variant,
//! * graph packing (CSR → device layout),
//! * end-to-end device solve vs native solve on the same graph.

use wbpr::coordinator::device::DeviceEngine;
use wbpr::graph::builder::ArcGraph;
use wbpr::graph::{generators, Bcsr, Rcsr};
use wbpr::maxflow::lockfree::{discharge_once, LocalCounters};
use wbpr::maxflow::state::ParState;
use wbpr::maxflow::{self, EngineKind, SolveOptions};
use wbpr::runtime::client::DeviceState;
use wbpr::runtime::pack::PackedGraph;
use wbpr::runtime::Runtime;
use wbpr::util::timer::{bench, black_box};

fn discharge_micro() {
    println!("## discharge_once (the Eq. 1 local operation)\n");
    let net = wbpr::bench::suite::with_pairs(
        generators::rmat(&generators::RmatParams { scale: 12, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19, seed: 1 }),
        4,
        2,
    );
    let g = ArcGraph::build(&net.normalized());
    let rcsr = Rcsr::build(&g);
    let bcsr = Bcsr::build(&g);
    let n = g.n as u32;
    let r1 = bench("discharge/RCSR (full sweep)", 1, 5, || {
        let (st, _) = ParState::preflow(&g);
        let mut c = LocalCounters::default();
        for u in 0..n {
            black_box(discharge_once(&g, &rcsr, &st, u, &mut c));
        }
    });
    let r2 = bench("discharge/BCSR (full sweep)", 1, 5, || {
        let (st, _) = ParState::preflow(&g);
        let mut c = LocalCounters::default();
        for u in 0..n {
            black_box(discharge_once(&g, &bcsr, &st, u, &mut c));
        }
    });
    for r in [r1, r2] {
        println!("{:<30} {:>9.3} ms/sweep ({:.1} ns/vertex)", r.name, r.mean_ms, r.mean_ms * 1e6 / n as f64);
    }
    println!();
}

fn device_micro() {
    let Ok(mut rt) = Runtime::from_default_location() else {
        println!("## device launch: skipped (run `make artifacts`)\n");
        return;
    };
    println!("## device launch latency (PJRT CPU, K cycles per launch)\n");
    for spec in rt.manifest().variants.clone() {
        if spec.kind != wbpr::runtime::artifact::VariantKind::Flow {
            continue; // relabel variants have a different ABI (4 inputs)
        }
        // A graph sized for this variant.
        let side = ((spec.v as f64 - 2.0).sqrt().floor() as usize).min(28).max(4);
        let net = generators::grid_road(side, side, 0.05, 4, 3);
        let g = ArcGraph::build(&net.normalized());
        let b = Bcsr::build(&g);
        let Ok(packed) = PackedGraph::pack(&g, &b, spec.v, spec.d) else {
            println!("{:<22} (packing does not fit, skipped)", spec.name);
            continue;
        };
        rt.ensure_compiled(&spec).unwrap();
        let mut state = DeviceState { cf: packed.cf0.clone(), e: vec![0.0; spec.v], h: packed.h0.clone() };
        packed.preflow(&mut state.cf, &mut state.e);
        let mut exec_ms = Vec::new();
        for _ in 0..10 {
            let mut s = state.clone();
            let r = rt.run_cycles(&spec, &packed, &mut s).unwrap();
            exec_ms.push(r.exec_ms);
        }
        let s = wbpr::util::stats::Summary::of(&exec_ms);
        println!(
            "{:<22} V={:<5} D={:<3} K={:<4} launch mean {:>7.3} ms (p50 {:.3}, {:.1} µs/cycle)",
            spec.name,
            spec.v,
            spec.d,
            spec.k,
            s.mean,
            s.p50,
            s.mean * 1e3 / spec.k as f64
        );
    }
    println!();
}

/// Read-only row sweeps through `chunk_window_scan` with both kernels,
/// on one hub row per degree class. The state is never mutated, so every
/// repetition scans identical data — pure kernel throughput, no
/// push-relabel control flow in the loop.
fn scan_micro() {
    use wbpr::graph::builder::FlowNetwork;
    use wbpr::graph::residual::Residual as _;
    use wbpr::graph::Edge;
    use wbpr::maxflow::scan::{chunk_window_scan, ScanKind, LANES};

    println!("## admissibility scan: scalar vs chunked ({LANES} lanes), read-only hub rows\n");
    for &deg in &[8usize, 64, 1024, 65536] {
        // Star hub 0 → 1 → deg leaves → sink, leaf heights scattered so
        // windows mix admissible and non-admissible lanes.
        let mut rng = wbpr::util::Rng::new(deg as u64 + 1);
        let n = deg + 3;
        let t = (n - 1) as u32;
        let mut edges = vec![Edge::new(0, 1, 1i64 << 40)];
        for i in 0..deg {
            let leaf = (i + 2) as u32;
            edges.push(Edge::new(1, leaf, 1 + (rng.next_u64() % 7) as i64));
            edges.push(Edge::new(leaf, t, 4));
        }
        let g = ArcGraph::build(&FlowNetwork::new(n, 0, t, edges, "scan-hub").normalized());
        let rep = Rcsr::build(&g);
        let (st, _) = ParState::preflow(&g);
        st.set_height(1, 3);
        for i in 0..deg {
            st.set_height((i + 2) as u32, (rng.next_u64() % 8) as u32);
        }
        let row = rep.row(1);
        let d = row.len();
        let hu = st.height(1);
        // Equal total work per degree class: ~4M arcs per measured iter.
        let reps = (4_000_000 / d.max(1)).max(4);
        for kind in [ScanKind::Scalar, ScanKind::Chunked] {
            let name = format!("scan/{}/deg {deg}", kind.name());
            let r = bench(&name, 1, 3, || {
                let mut arcs = 0u64;
                for _ in 0..reps {
                    let win = row.slice_segs(0, d);
                    black_box(chunk_window_scan(&st, &win, hu, kind, &mut arcs, |a, v| {
                        black_box((a, v));
                    }));
                }
                black_box(arcs);
            });
            let total_arcs = (reps * d) as f64;
            println!(
                "{:<26} {:>9.3} ms | {:>7.2} ns/arc | {:>8.1} M arcs/s",
                r.name,
                r.mean_ms,
                r.mean_ms * 1e6 / total_arcs,
                total_arcs / (r.mean_ms * 1e3)
            );
        }
        println!();
    }
}

/// Global-relabel BFS over a preflow state, per graph class: the
/// sequential backward BFS vs the parallel level-synchronous pass on an
/// 8-worker pool, plus the forced top-down / bottom-up ablations of the
/// direction switch. Heights are rewritten by every pass, so repeated
/// calls measure the steady-state BFS, not a warm-up artifact.
fn gr_micro() {
    use wbpr::maxflow::global_relabel::{global_relabel_in, ExcessAccounting, GrScratch};
    use wbpr::maxflow::{GrDirection, GrMode, WorkerPool};

    println!("## global relabel: sequential vs parallel (8 workers) vs forced directions\n");
    let cases: Vec<(&str, ArcGraph)> = vec![
        (
            "rmat-14",
            ArcGraph::build(&generators::rmat(&generators::RmatParams {
                scale: 14,
                edge_factor: 8,
                a: 0.57,
                b: 0.19,
                c: 0.19,
                seed: 7,
            })),
        ),
        (
            "genrmf-16x16",
            ArcGraph::build(&generators::genrmf(&generators::GenrmfParams {
                a: 16,
                b: 16,
                c1: 1,
                c2: 100,
                seed: 11,
            })),
        ),
        (
            "washington-64",
            ArcGraph::build(&generators::washington_rlg(&generators::WashingtonParams {
                levels: 64,
                width: 64,
                fanout: 2,
                max_cap: 64,
                seed: 5,
            })),
        ),
        ("star-hub-32k", ArcGraph::build(&generators::star_hub(1 << 15, 1 << 12, 3))),
    ];
    let pool = WorkerPool::new(8);
    for (name, g) in &cases {
        let rep = Bcsr::build(g);
        let (st, total) = ParState::preflow(g);
        let mut scratch = GrScratch::new(g.n);
        let mut seq_ms = 0.0;
        let arms = [
            ("seq", GrMode::sequential()),
            ("par/auto", GrMode { pool: Some(&pool), direction: GrDirection::Auto }),
            ("par/top-down", GrMode { pool: Some(&pool), direction: GrDirection::TopDown }),
            ("par/bottom-up", GrMode { pool: Some(&pool), direction: GrDirection::BottomUp }),
        ];
        for (arm, mode) in arms {
            let r = bench(&format!("gr/{name}/{arm}"), 1, 5, || {
                let mut acct = ExcessAccounting::new(g.n, total);
                black_box(global_relabel_in(g, &rep, &st, &mut acct, true, &mut scratch, mode));
            });
            if arm == "seq" {
                seq_ms = r.mean_ms;
            }
            println!(
                "{:<28} {:>9.3} ms/pass | {:>5.2}x vs seq",
                r.name,
                r.mean_ms,
                seq_ms / r.mean_ms.max(1e-9)
            );
        }
        println!();
    }
}

fn pack_micro() {
    println!("## packing (CSR -> device layout)\n");
    let net = generators::grid_road(30, 30, 0.05, 12, 7);
    let g = ArcGraph::build(&net.normalized());
    let b = Bcsr::build(&g);
    let r = bench("pack v1024_d32", 2, 20, || {
        black_box(PackedGraph::pack(&g, &b, 1024, 32).unwrap());
    });
    println!("{:<22} {:>9.3} ms\n", r.name, r.mean_ms);
}

fn e2e_compare() {
    let Ok(eng) = DeviceEngine::from_default_location() else {
        println!("## device vs native: skipped (run `make artifacts`)\n");
        return;
    };
    let mut eng = eng;
    println!("## end-to-end: device vs native on the same graph\n");
    let net = generators::grid_road(30, 30, 0.05, 12, 7);
    let g = ArcGraph::build(&net.normalized());
    let cold = eng.solve(&g).unwrap(); // includes one-time XLA compilation
    let warm = eng.solve(&g).unwrap(); // executable cached
    let native = maxflow::solve_arcs(&g, EngineKind::VertexCentric, wbpr::graph::Representation::Bcsr, &SolveOptions::default());
    assert_eq!(cold.value, native.value);
    assert_eq!(warm.value, native.value);
    println!(
        "device cold: {:>8.1} ms total ({:.1} exec, {} launches)  [includes XLA compile]",
        cold.stats.total_ms, cold.stats.kernel_ms, cold.stats.launches
    );
    println!(
        "device warm: {:>8.1} ms total ({:.1} exec, {} launches)",
        warm.stats.total_ms, warm.stats.kernel_ms, warm.stats.launches
    );
    println!("native VC+BCSR: {:>6.1} ms | flow={}", native.stats.total_ms, cold.value);
}

fn main() {
    println!("# Kernel microbenchmarks\n");
    discharge_micro();
    scan_micro();
    gr_micro();
    pack_micro();
    device_micro();
    e2e_compare();
}
