//! `cargo bench --bench ablation_csr` — ablations of the design choices
//! DESIGN.md calls out:
//!
//! 1. **Representation access costs** in isolation: RCSR's O(1)
//!    reverse-arc lookup vs BCSR's binary search, and row-scan locality —
//!    microbenchmarked on real graphs.
//! 2. **Global relabel on/off** (the He & Hong heuristic the paper keeps).
//! 3. **cycles_per_launch** sweep (the `cycle` parameter of Alg. 1).
//! 4. **Degree skew sweep**: where the VC-over-TC crossover sits in the
//!    SIMT model (the paper's §4.2 "high degree std-dev" claim).

use wbpr::graph::builder::ArcGraph;
use wbpr::graph::residual::Residual;
use wbpr::graph::{generators, Bcsr, Rcsr, Representation};
use wbpr::maxflow::{self, SolveOptions};
use wbpr::simt::exec::{simulate_tc, simulate_vc};
use wbpr::simt::trace::record;
use wbpr::simt::{CostParams, GpuModel};
use wbpr::util::timer::{bench, black_box};

fn rep_access_costs() {
    println!("## Ablation 1 — representation access costs (microbench)\n");
    let net = wbpr::bench::suite::with_pairs(
        generators::rmat(&generators::RmatParams { scale: 12, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19, seed: 3 }),
        4,
        33,
    );
    let g = ArcGraph::build(&net.normalized());
    let rcsr = Rcsr::build(&g);
    let bcsr = Bcsr::build(&g);
    println!("graph: V={} E={} | RCSR {} KB, BCSR {} KB", g.n, g.num_arcs() / 2, rcsr.memory_bytes() / 1024, bcsr.memory_bytes() / 1024);

    // Row scans (the k*d(v) term).
    let scan = |rep: &dyn Fn(u32) -> u64| {
        let mut acc = 0u64;
        for u in 0..g.n as u32 {
            acc = acc.wrapping_add(rep(u));
        }
        acc
    };
    let r1 = bench("rcsr-scan", 2, 10, || {
        black_box(scan(&|u| rcsr.row(u).iter().map(|(a, _)| a as u64).sum()));
    });
    let r2 = bench("bcsr-scan", 2, 10, || {
        black_box(scan(&|u| bcsr.row(u).iter().map(|(a, _)| a as u64).sum()));
    });
    // Reverse-arc lookups (the push-side cost: O(1) vs O(log d)).
    let arcs: Vec<(u32, u32, u32)> = (0..g.n as u32)
        .flat_map(|u| rcsr.row(u).iter().map(move |(a, v)| (a, u, v)).collect::<Vec<_>>())
        .collect();
    let r3 = bench("rcsr-rev", 2, 10, || {
        let mut acc = 0u64;
        for &(a, u, v) in &arcs {
            acc = acc.wrapping_add(rcsr.rev_arc(a, u, v) as u64);
        }
        black_box(acc);
    });
    let r4 = bench("bcsr-rev(binary-search)", 2, 10, || {
        let mut acc = 0u64;
        for &(a, u, v) in &arcs {
            acc = acc.wrapping_add(bcsr.rev_arc(a, u, v) as u64);
        }
        black_box(acc);
    });
    for r in [r1, r2, r3, r4] {
        println!("{:<26} {:>9.3} ms/iter (min {:.3})", r.name, r.mean_ms, r.min_ms);
    }
    println!();
}

fn global_relabel_ablation() {
    println!("## Ablation 2 — global relabel heuristic on/off\n");
    let net = generators::washington_rlg(&generators::WashingtonParams { levels: 48, width: 48, fanout: 3, max_cap: 50, seed: 5 });
    let g = ArcGraph::build(&net.normalized());
    let rep = Bcsr::build(&g);
    for (label, gr) in [("with global relabel", true), ("accounting only", false)] {
        let opts = SolveOptions { cycles_per_launch: 256, global_relabel: gr, ..Default::default() };
        let r = maxflow::vc::solve(&g, &rep, &opts);
        println!("{label:<22} {:>9.1} ms  launches={} cycles={}", r.stats.total_ms, r.stats.launches, r.stats.cycles);
    }
    println!();
}

fn cycles_sweep() {
    println!("## Ablation 3 — cycles per launch (Alg. 1 `cycle`)\n");
    let net = wbpr::bench::suite::with_pairs(
        generators::rmat(&generators::RmatParams { scale: 12, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19, seed: 9 }),
        4,
        99,
    );
    let g = ArcGraph::build(&net.normalized());
    let rep = Bcsr::build(&g);
    let want = maxflow::dinic::solve(&g).value;
    for cycles in [32, 128, 512, 2048] {
        let opts = SolveOptions { cycles_per_launch: cycles, ..Default::default() };
        let r = maxflow::vc::solve(&g, &rep, &opts);
        assert_eq!(r.value, want);
        println!("cycles={cycles:<5} {:>9.1} ms  launches={:<4} relabels={}", r.stats.total_ms, r.stats.launches, r.stats.relabels);
    }
    println!();
}

fn skew_crossover() {
    println!("## Ablation 4 — degree-skew crossover (SIMT model)\n");
    println!("{:<28} {:>10} {:>10} {:>9}", "graph", "TC ms", "VC ms", "TC/VC");
    let (model, costs) = (GpuModel::default(), CostParams::default());
    let cases: Vec<(String, wbpr::graph::builder::FlowNetwork)> = vec![
        ("near-regular (R0 regime)".into(), wbpr::bench::suite::with_pairs(generators::near_regular(4000, 5, 1), 4, 2)),
        ("road mesh (R1 regime)".into(), wbpr::bench::suite::with_pairs(generators::grid_road(64, 64, 0.08, 20, 3), 4, 4)),
        (
            "rmat skew a=.50".into(),
            wbpr::bench::suite::with_pairs(
                generators::rmat(&generators::RmatParams { scale: 12, edge_factor: 8, a: 0.50, b: 0.22, c: 0.22, seed: 5 }),
                4,
                6,
            ),
        ),
        (
            "rmat skew a=.57".into(),
            wbpr::bench::suite::with_pairs(
                generators::rmat(&generators::RmatParams { scale: 12, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19, seed: 5 }),
                4,
                6,
            ),
        ),
        (
            "rmat skew a=.63 (R5 regime)".into(),
            generators_with_pairs_scaled(0.63),
        ),
    ];
    for (name, net) in cases {
        let g = ArcGraph::build(&net.normalized());
        let rcsr = Rcsr::build(&g);
        let trace = record(&g, &rcsr, 128);
        let tc = simulate_tc(&trace, Representation::Rcsr, &model, &costs);
        let vc = simulate_vc(&trace, Representation::Rcsr, &model, &costs);
        println!("{name:<28} {:>10.1} {:>10.1} {:>8.2}x", tc.ms, vc.ms, tc.ms / vc.ms);
    }
    println!("\n(the paper's claim: the VC win grows with degree std-dev; flat graphs favor TC)");
}

fn generators_with_pairs_scaled(a: f64) -> wbpr::graph::builder::FlowNetwork {
    let rest = (1.0 - a) / 2.3;
    wbpr::bench::suite::with_pairs(
        generators::rmat(&generators::RmatParams { scale: 12, edge_factor: 8, a, b: rest, c: rest, seed: 5 }),
        4,
        6,
    )
}

fn main() {
    println!("# Ablations — CSR representations, heuristics, schedule parameters\n");
    rep_access_costs();
    global_relabel_ablation();
    cycles_sweep();
    skew_crossover();
}
