//! Property-based invariants (in-repo harness, `util::prop`): randomized
//! graphs, all the algebraic facts the paper's correctness rests on.

use wbpr::dynamic::{DynamicFlow, GraphUpdate, UpdateBatch};
use wbpr::graph::builder::{ArcGraph, FlowNetwork};
use wbpr::graph::residual::Residual;
use wbpr::graph::{dimacs, generators, Bcsr, Rcsr, Representation};
use wbpr::maxflow::{self, EngineKind, SolveOptions};
use wbpr::util::prop::{check, Gen};

fn random_net(g: &mut Gen) -> FlowNetwork {
    let n = g.size(4, 60).max(4);
    let m = g.size(n, n * 6);
    let cap = g.size(1, 12) as i64;
    generators::erdos_renyi(n, m, cap, g.rng.next_u64())
}

#[test]
fn prop_flow_value_is_engine_invariant() {
    check("engine-invariant flow value", 40, 0xF10, |g| {
        let net = random_net(g);
        let arcs = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&arcs).value;
        let opts = SolveOptions { threads: 2, cycles_per_launch: 32, ..Default::default() };
        for kind in [EngineKind::Sequential, EngineKind::VertexCentric] {
            let got = maxflow::solve_arcs(&arcs, kind, Representation::Bcsr, &opts);
            if got.value != want {
                return Err(format!("{} got {} want {want} on {}", kind.name(), got.value, net.name));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_maxflow_equals_mincut() {
    // Max-flow = min-cut: the verifier checks residual s-t disconnection +
    // conservation; additionally compute the cut capacity across the
    // reachable set and compare with the value.
    check("maxflow = mincut", 40, 0xCA7, |g| {
        let net = random_net(g);
        let arcs = ArcGraph::build(&net.normalized());
        let r = maxflow::seq::solve(&arcs);
        maxflow::verify(&arcs, &r)?;
        // S = residual-reachable from s; cut = sum of original caps S->T.
        let m2 = arcs.num_arcs();
        let mut seen = vec![false; arcs.n];
        let mut stack = vec![arcs.s];
        seen[arcs.s as usize] = true;
        let (csr, aid) = wbpr::graph::csr::Csr::from_pairs_with(
            arcs.n,
            (0..m2 as u32).map(|a| (arcs.arc_from[a as usize], arcs.arc_to[a as usize], a)),
        );
        while let Some(u) = stack.pop() {
            for i in csr.range(u) {
                let a = aid[i] as usize;
                let v = csr.cols[i] as usize;
                if r.cf[a] > 0 && !seen[v] {
                    seen[v] = true;
                    stack.push(v as u32);
                }
            }
        }
        let mut cut = 0i64;
        for a in (0..m2).step_by(2) {
            if seen[arcs.arc_from[a] as usize] && !seen[arcs.arc_to[a] as usize] {
                cut += arcs.arc_cap[a];
            }
        }
        if cut != r.value {
            return Err(format!("cut {cut} != flow {}", r.value));
        }
        Ok(())
    });
}

#[test]
fn prop_representations_expose_identical_neighborhoods() {
    check("RCSR == BCSR neighborhoods", 60, 0xBEEF, |g| {
        let net = random_net(g);
        let arcs = ArcGraph::build(&net.normalized());
        let rcsr = Rcsr::build(&arcs);
        let bcsr = Bcsr::build(&arcs);
        for u in 0..arcs.n as u32 {
            let mut a: Vec<(u32, u32)> = rcsr.row(u).iter().collect();
            let mut b: Vec<(u32, u32)> = bcsr.row(u).iter().collect();
            a.sort_unstable();
            b.sort_unstable();
            if a != b {
                return Err(format!("row {u} differs between representations"));
            }
            for (arc, v) in a {
                let ra = rcsr.rev_arc(arc, u, v);
                let rb = bcsr.rev_arc(arc, u, v);
                if ra != rb || ra != (arc ^ 1) {
                    return Err(format!("rev mismatch arc {arc}: rcsr {ra} bcsr {rb}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dimacs_roundtrip() {
    check("dimacs roundtrip", 40, 0xD1AC, |g| {
        let net = random_net(g).normalized();
        let text = dimacs::write(&net);
        let back = dimacs::parse(&text).map_err(|e| e)?;
        if back.n != net.n || back.s != net.s || back.t != net.t || back.edges != net.edges {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_matching_via_flow_equals_hopcroft_karp() {
    check("matching == hopcroft-karp", 30, 0x3A7C, |g| {
        let nl = g.size(2, 40).max(2);
        let nr = g.size(2, 40).max(2);
        let m = g.size(1, nl * 4);
        let skew = if g.rng.chance(0.5) { 1.2 } else { 0.0 };
        let bg = wbpr::graph::bipartite::bipartite_zipf(nl, nr, m, skew, g.rng.next_u64());
        let want = maxflow::hopcroft_karp::solve(&bg).size;
        let opts = SolveOptions { threads: 2, cycles_per_launch: 32, ..Default::default() };
        let fm = maxflow::matching::solve(&bg, EngineKind::VertexCentric, Representation::Rcsr, &opts);
        if fm.matching.size != want {
            return Err(format!("flow matching {} != hk {want}", fm.matching.size));
        }
        maxflow::hopcroft_karp::validate(&bg, &fm.matching)
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    check("device pack/unpack roundtrip", 40, 0x9ACC, |g| {
        let net = random_net(g);
        let arcs = ArcGraph::build(&net.normalized());
        let bcsr = Bcsr::build(&arcs);
        let maxdeg = (0..arcs.n as u32).map(|u| bcsr.degree(u)).max().unwrap_or(0);
        let v_pad = arcs.n.next_power_of_two().max(4);
        let d_pad = maxdeg.next_power_of_two().max(2);
        let p = wbpr::runtime::PackedGraph::pack(&arcs, &bcsr, v_pad, d_pad).map_err(|e| e)?;
        let mut out = vec![0i64; arcs.num_arcs()];
        p.unpack_cf(&p.cf0, &mut out);
        if out != arcs.arc_cap {
            return Err("unpack(pack(cf0)) != arc caps".into());
        }
        // rev slots form an involution.
        for (f, &a) in p.slot_arc.iter().enumerate() {
            if a != u32::MAX {
                let r = p.rev[f] as usize;
                if p.rev[r] as usize != f {
                    return Err(format!("rev not involutive at slot {f}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_pairs() {
    check("batcher conservation", 40, 0xBA7C, |g| {
        let base = generators::grid_road(6 + g.size(0, 6), 6 + g.size(0, 6), 0.05, 4, g.rng.next_u64());
        let max_pairs = 1 + g.size(1, 5);
        let mut b = wbpr::coordinator::batcher::PairBatcher::new(base.clone(), 100, max_pairs);
        let n_pairs = g.size(1, 12).max(1);
        let mut submitted = 0usize;
        let mut collected = 0usize;
        for _ in 0..n_pairs {
            let s = g.rng.index(base.n) as u32;
            let t = g.rng.index(base.n) as u32;
            if s == t {
                continue;
            }
            submitted += 1;
            if let Some(batch) = b.add(s, t) {
                collected += batch.pairs.len();
                batch.net.validate()?;
            }
        }
        if let Some(batch) = b.flush() {
            collected += batch.pairs.len();
        }
        if submitted != collected {
            return Err(format!("submitted {submitted} != collected {collected}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dynamic_repair_equals_scratch_dinic() {
    // After every randomized update batch, the incremental engine must
    // hold a *verified* max flow (maxflow::verify: antisymmetry, value
    // accounting, no augmenting path) whose value equals a from-scratch
    // Dinic solve of the mutated network.
    check("dynamic repair == scratch", 25, 0xDF10, |g| {
        let net = random_net(g);
        let opts = SolveOptions { threads: 2, cycles_per_launch: 32, ..Default::default() };
        let mut df = DynamicFlow::new(&net, &opts);
        let n_batches = 1 + g.size(1, 5);
        for bi in 0..n_batches {
            let m = df.network().edges.len();
            let n_ups = 1 + g.size(0, 6);
            let mut ups = Vec::new();
            for _ in 0..n_ups {
                let roll = g.rng.f64();
                if roll < 0.35 {
                    ups.push(GraphUpdate::IncreaseCap { edge: g.rng.index(m), delta: g.rng.range_i64(1, 9) });
                } else if roll < 0.70 {
                    ups.push(GraphUpdate::DecreaseCap { edge: g.rng.index(m), delta: g.rng.range_i64(1, 9) });
                } else if roll < 0.85 {
                    let u = g.rng.index(df.network().n) as u32;
                    let v = g.rng.index(df.network().n) as u32;
                    if u != v {
                        ups.push(GraphUpdate::InsertEdge { u, v, cap: g.rng.range_i64(1, 9) });
                    }
                } else {
                    ups.push(GraphUpdate::DeleteEdge { edge: g.rng.index(m) });
                }
            }
            let report = df.apply(&UpdateBatch::new(ups)).map_err(|e| format!("apply failed: {e}"))?;
            let scratch = maxflow::dinic::solve(&ArcGraph::build(&df.network().normalized()));
            if report.value != scratch.value {
                return Err(format!(
                    "batch {bi} on {}: incremental {} != dinic {}",
                    net.name, report.value, scratch.value
                ));
            }
            maxflow::verify(df.arcs(), &df.flow_result())
                .map_err(|e| format!("batch {bi} on {}: verify: {e}", net.name))?;
        }
        Ok(())
    });
}

#[test]
fn prop_carried_frontier_covers_active_set() {
    // ISSUE 4 satellite: after any launch whose carried frontier survives
    // the host step, the frontier must cover exactly the live active set
    // — `SolveOptions::verify_frontier` runs the O(V) reference scan
    // (every active vertex queued, no terminals, no duplicates) inside
    // the engine after each such launch and panics on violation; the prop
    // harness converts the panic into a failing case. The thread sweep
    // {1, 8, threads > n} includes oversubscription to shake out
    // epoch-stamp races.
    check("carried frontier == active set", 20, 0xF407, |g| {
        let net = random_net(g);
        let arcs = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&arcs).value;
        for threads in [1usize, 8, arcs.n + 3] {
            // A tiny launch budget maximizes launch boundaries (the thing
            // under test).
            let opts = SolveOptions {
                threads,
                cycles_per_launch: 4,
                verify_frontier: true,
                ..Default::default()
            };
            let r = maxflow::solve_arcs(&arcs, EngineKind::VertexCentric, Representation::Rcsr, &opts);
            if r.value != want {
                return Err(format!("threads={threads} on {}: {} != {want}", net.name, r.value));
            }
            // With height-updating relabels only the cold first launch
            // rescans — relabels re-seed, gap cuts leave the carry valid.
            if r.stats.rescan_launches > 1 {
                return Err(format!(
                    "threads={threads} on {}: unexplained rescans ({} rescans / {} launches)",
                    net.name, r.stats.rescan_launches, r.stats.launches
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_coop_multipush_preserves_carry_invariant_on_hubs() {
    // ISSUE 5 satellite: multi-push + cooperative hub discharge must
    // preserve the `verify_frontier` carry-over invariant (every active
    // vertex queued, no terminals/duplicates) across threads {1, 8, n+3},
    // on hub-skewed instances where the chunk path does the bulk of the
    // work. The in-engine O(V) reference scan panics on violation; the
    // prop harness converts that into a failing case.
    check("coop+multi-push carry invariant on hubs", 12, 0xC0B5, |g| {
        let leaves = 40 + g.size(0, 80);
        let extra = 30 + g.size(0, 60);
        let net = generators::star_hub(leaves, extra, g.rng.next_u64());
        let arcs = ArcGraph::build(&net);
        let want = maxflow::dinic::solve(&arcs).value;
        for threads in [1usize, 8, arcs.n + 3] {
            // coop_degree forced low + a tiny launch budget: maximal
            // chunk traffic across maximal launch boundaries.
            let opts = SolveOptions {
                threads,
                cycles_per_launch: 4,
                coop_degree: 8,
                coop_chunk: 4,
                verify_frontier: true,
                ..Default::default()
            };
            let r = maxflow::solve_arcs(&arcs, EngineKind::VertexCentric, Representation::Rcsr, &opts);
            if r.value != want {
                return Err(format!("threads={threads} on {}: {} != {want}", net.name, r.value));
            }
            if r.stats.coop_chunks == 0 {
                return Err(format!("threads={threads} on {}: coop path never ran", net.name));
            }
            // Single-push ablation under the same schedule pressure.
            let single = SolveOptions { multi_push: false, ..opts };
            let rs = maxflow::solve_arcs(&arcs, EngineKind::VertexCentric, Representation::Bcsr, &single);
            if rs.value != want {
                return Err(format!("threads={threads} single-push on {}: {} != {want}", net.name, rs.value));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_scan_agrees_with_scalar_across_threads() {
    // ISSUE 7: the lane-chunked admissibility kernel (in-place multi-push
    // rows *and* cooperative hub windows) against the scalar fallback,
    // across threads {1, 8, n+3} on hub-skewed instances, with the chunk
    // tuner active on the chunked arm — values, decomposition validity
    // and the carry invariant must all agree.
    check("chunked scan == scalar scan", 12, 0x5CA2, |g| {
        let leaves = 40 + g.size(0, 80);
        let extra = 30 + g.size(0, 60);
        let net = generators::star_hub(leaves, extra, g.rng.next_u64());
        let arcs = ArcGraph::build(&net);
        let want = maxflow::dinic::solve(&arcs).value;
        for threads in [1usize, 8, arcs.n + 3] {
            let base = SolveOptions {
                threads,
                cycles_per_launch: 8,
                coop_degree: 8,
                coop_chunk: 4,
                verify_frontier: true,
                ..Default::default()
            };
            let scalar = SolveOptions { scan: wbpr::maxflow::ScanKind::Scalar, ..base.clone() };
            let chunked = SolveOptions {
                scan: wbpr::maxflow::ScanKind::Chunked,
                adaptive_chunk: true,
                ..base
            };
            let rs = maxflow::vc::solve(&arcs, &Rcsr::build(&arcs), &scalar);
            let rc = maxflow::vc::solve(&arcs, &Bcsr::build(&arcs), &chunked);
            if rs.value != want || rc.value != want {
                return Err(format!(
                    "threads={threads} on {}: scalar {} / chunked {} != {want}",
                    net.name, rs.value, rc.value
                ));
            }
            maxflow::verify(&arcs, &rs).map_err(|e| format!("scalar threads={threads}: {e}"))?;
            maxflow::verify(&arcs, &rc).map_err(|e| format!("chunked threads={threads}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_snapshot_roundtrip_preserves_session_behavior() {
    // ISSUE 4 satellite: FlowSnapshot -> from_snapshot -> one more update
    // batch must produce the same value *and* the same
    // `UpdateReport.recomputed` routing decision as the session that was
    // never evicted, for a random eviction point mid-stream.
    check("snapshot roundtrip == never-evicted", 15, 0x5A9, |g| {
        let net = random_net(g);
        // threads = 1 keeps the ops counters (and hence both sessions'
        // cost models) deterministic; the generous recompute margin keeps
        // the routing comparison meaningful without making it knife-edge
        // on the EWMA the eviction legitimately resets.
        let opts = SolveOptions { threads: 1, cycles_per_launch: 32, ..Default::default() };
        let pool = std::sync::Arc::new(maxflow::WorkerPool::new(1));
        let cfg = wbpr::coordinator::SessionConfig {
            router: wbpr::coordinator::RouterConfig { recompute_ratio: 8.0, ..Default::default() },
            ..Default::default()
        };
        let mut live = wbpr::coordinator::SessionManager::with_config(opts.clone(), pool.clone(), cfg.clone());
        let mut evicting = wbpr::coordinator::SessionManager::with_config(opts.clone(), pool, cfg);
        live.open(1, &net)?;
        evicting.open(1, &net)?;
        let n_batches = 2 + g.size(0, 4);
        let evict_at = g.rng.index(n_batches);
        for bi in 0..n_batches {
            if bi == evict_at {
                evicting.evict(1).map_err(|e| format!("evict: {e}"))?;
                if evicting.evicted_len() != 1 {
                    return Err("eviction did not persist a snapshot".into());
                }
            }
            // Capacity-only batch over the shared (index-stable) edge list.
            let m = live.get(1).expect("live session").network().edges.len();
            let n_ups = 1 + g.size(0, 4);
            let mut ups = Vec::new();
            for _ in 0..n_ups {
                if g.rng.chance(0.5) {
                    ups.push(GraphUpdate::IncreaseCap { edge: g.rng.index(m), delta: g.rng.range_i64(1, 6) });
                } else {
                    ups.push(GraphUpdate::DecreaseCap { edge: g.rng.index(m), delta: g.rng.range_i64(1, 6) });
                }
            }
            let batch = UpdateBatch::new(ups);
            let a = live.update_report(1, &batch).map_err(|e| format!("live: {e}"))?;
            let b = evicting.update_report(1, &batch).map_err(|e| format!("evicted: {e}"))?;
            if a.value != b.value {
                return Err(format!(
                    "batch {bi} (evict at {evict_at}) on {}: live {} != roundtrip {}",
                    net.name, a.value, b.value
                ));
            }
            if a.recomputed != b.recomputed {
                return Err(format!(
                    "batch {bi} (evict at {evict_at}) on {}: routing diverged (live recomputed={}, roundtrip={})",
                    net.name, a.recomputed, b.recomputed
                ));
            }
        }
        // Both sessions hold verified, identical flows at the end.
        let va = live.close(1)?;
        let vb = evicting.close(1)?;
        if va != vb {
            return Err(format!("final values differ: {va} != {vb}"));
        }
        Ok(())
    });
}

#[test]
fn prop_excess_never_negative_midway() {
    // Run the trace recorder (a legal lock-free schedule) and check the
    // invariants the Jacobi-combine proof relies on.
    check("nonnegative excess/cf", 20, 0xE0, |g| {
        let net = random_net(g);
        let arcs = ArcGraph::build(&net.normalized());
        let rep = Rcsr::build(&arcs);
        let trace = wbpr::simt::trace::record(&arcs, &rep, 16);
        if trace.value < 0 {
            return Err("negative flow value".into());
        }
        let want = maxflow::dinic::solve(&arcs).value;
        if trace.value != want {
            return Err(format!("trace {} != dinic {want}", trace.value));
        }
        Ok(())
    });
}
