//! Coordinator integration: mixed concurrent workloads, routing behaviour,
//! batching pipeline, metrics consistency, failure injection.

use std::collections::HashMap;
use wbpr::coordinator::batcher::PairBatcher;
use wbpr::coordinator::{Coordinator, CoordinatorConfig, Job};
use wbpr::graph::bipartite::bipartite_planted;
use wbpr::graph::builder::ArcGraph;
use wbpr::graph::{generators, Representation};
use wbpr::maxflow::{self, EngineKind, SolveOptions};

fn config(native: usize, device: bool) -> CoordinatorConfig {
    CoordinatorConfig {
        native_workers: native,
        enable_device: device,
        solve: SolveOptions { threads: 2, cycles_per_launch: 64, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn mixed_workload_all_verified() {
    let coord = Coordinator::start(config(3, true));
    let mut expected: HashMap<u64, i64> = HashMap::new();
    // Max-flow jobs across engines.
    for seed in 0..4u64 {
        let net = generators::erdos_renyi(40, 220, 5, seed);
        let g = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&g).value;
        for kind in [EngineKind::ThreadCentric, EngineKind::VertexCentric] {
            let id = coord.submit(Job::MaxFlow { net: net.clone(), kind, rep: Representation::Bcsr });
            expected.insert(id, want);
        }
        let id = coord.submit(Job::MaxFlowAuto { net });
        expected.insert(id, want);
    }
    // Matching jobs.
    for seed in 0..3u64 {
        let bg = bipartite_planted(15, 25, 40, seed);
        let want = maxflow::hopcroft_karp::solve(&bg).size as i64;
        let id = coord.submit(Job::Matching { graph: bg, kind: EngineKind::VertexCentric, rep: Representation::Rcsr });
        expected.insert(id, want);
    }
    let outs = coord.collect(expected.len());
    assert_eq!(outs.len(), expected.len());
    for o in outs {
        let v = o.result.expect("job ok");
        assert_eq!(v.value, expected[&o.id], "job {}", o.id);
    }
    let metrics = coord.shutdown();
    let total_jobs: u64 = metrics.snapshot().values().map(|e| e.jobs).sum();
    assert_eq!(total_jobs as usize, expected.len(), "metrics count every job");
}

#[test]
fn batched_pipeline_through_coordinator() {
    let coord = Coordinator::start(config(2, true));
    let base = generators::grid_road(16, 16, 0.05, 6, 3);
    let pairs = wbpr::graph::builder::select_pairs(&base, 8, 16, 5);
    let mut batcher = PairBatcher::new(base, 1 << 16, 3);
    let mut expected = HashMap::new();
    let mut submitted = 0;
    for &(s, t) in &pairs {
        let batch = batcher.add(s, t);
        if let Some(b) = batch {
            let g = ArcGraph::build(&b.net.normalized());
            let want = maxflow::dinic::solve(&g).value;
            expected.insert(coord.submit(Job::MaxFlowAuto { net: b.net }), want);
            submitted += 1;
        }
    }
    if let Some(b) = batcher.flush() {
        let g = ArcGraph::build(&b.net.normalized());
        let want = maxflow::dinic::solve(&g).value;
        expected.insert(coord.submit(Job::MaxFlowAuto { net: b.net }), want);
        submitted += 1;
    }
    assert!(submitted >= 2);
    for o in coord.collect(submitted) {
        let v = o.result.expect("batch ok");
        assert_eq!(v.value, expected[&o.id]);
    }
}

#[test]
fn no_device_config_still_serves_everything() {
    let coord = Coordinator::start(config(2, false));
    assert!(!coord.has_device());
    let net = generators::erdos_renyi(30, 150, 4, 9);
    let g = ArcGraph::build(&net.normalized());
    let want = maxflow::dinic::solve(&g).value;
    coord.submit(Job::MaxFlowAuto { net });
    let out = coord.recv().unwrap();
    let v = out.result.unwrap();
    assert_eq!(v.value, want);
    assert!(v.engine.starts_with("native"));
}

#[test]
fn results_match_ids_under_contention() {
    let coord = Coordinator::start(config(4, false));
    let mut expected = HashMap::new();
    for seed in 0..24u64 {
        // Different graphs => different values; ids must not get crossed.
        let net = generators::erdos_renyi(20 + (seed as usize % 7) * 5, 120, 3 + (seed % 4) as i64, seed);
        let g = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&g).value;
        expected.insert(coord.submit(Job::MaxFlow { net, kind: EngineKind::Sequential, rep: Representation::Rcsr }), want);
    }
    for o in coord.collect(24) {
        assert_eq!(o.result.unwrap().value, expected[&o.id], "id {}", o.id);
    }
}

#[test]
fn latency_timer_includes_queue_time() {
    let coord = Coordinator::start(config(1, false));
    // Saturate the single worker; later jobs must report larger latency.
    let net = generators::erdos_renyi(60, 400, 6, 1);
    let mut ids = Vec::new();
    for _ in 0..6 {
        ids.push(coord.submit(Job::MaxFlow {
            net: net.clone(),
            kind: EngineKind::Sequential,
            rep: Representation::Rcsr,
        }));
    }
    let outs = coord.collect(6);
    let mut by_id: Vec<(u64, f64)> = outs.into_iter().map(|o| (o.id, o.result.unwrap().ms)).collect();
    by_id.sort_unstable_by_key(|x| x.0);
    // Last-submitted should have waited at least as long as the first
    // finished (weak monotonicity check with slack for scheduling noise).
    assert!(by_id.last().unwrap().1 >= by_id.first().unwrap().1 * 0.5);
}
