//! Differential test oracle (ISSUE 4 satellite): the full seeded sweep of
//! `wbpr::maxflow::oracle` cases — frontier-VC, legacy-VC, Dinic and
//! Edmonds–Karp must produce identical max-flow values and valid flow
//! decompositions (capacity + conservation + maximality on the residual)
//! on every case.
//!
//! Part of tier-1 (`cargo test -q`); CI additionally runs it as its own
//! release-mode job (`cargo test --release -q --test oracle`). The seed
//! list lives in `tests/data/oracle_seeds.txt`, which the bench-regression
//! job hashes into its cache key so a baseline and a candidate always
//! compare identical cases.

use wbpr::maxflow::oracle::{build_case, run_case, run_dynamic_case, sweep};

/// Parse the checked-in seed list (one or more seeds per line, `#`
/// comments).
fn seeds() -> Vec<u64> {
    let raw = include_str!("data/oracle_seeds.txt");
    let seeds: Vec<u64> = raw
        .lines()
        .map(|l| l.split('#').next().unwrap_or(""))
        .flat_map(|l| l.split_whitespace().map(|t| t.parse::<u64>().expect("seed list: bad token")))
        .collect();
    assert!(seeds.len() >= 40, "oracle sweep must keep ~40 cases, got {}", seeds.len());
    seeds
}

#[test]
fn oracle_sweep_all_engines_agree() {
    let cases = sweep(&seeds());
    let mut nonzero = 0usize;
    for case in &cases {
        let report = run_case(case, 3).unwrap_or_else(|e| panic!("oracle disagreement: {e}"));
        if report.value > 0 {
            nonzero += 1;
        }
    }
    // The sweep must actually exercise flow routing, not degenerate to
    // empty instances.
    assert!(
        nonzero * 2 >= cases.len(),
        "only {nonzero}/{} oracle cases carried flow — sweep too weak",
        cases.len()
    );
}

#[test]
fn oracle_sweep_covers_every_family() {
    let seeds = seeds();
    for family in 0..4u64 {
        assert!(
            seeds.iter().any(|s| *s < 1000 && s % 4 == family),
            "seed list lost family {family} (rmat/genrmf/washington/bipartite)"
        );
    }
    // The hub band (>= 1000) must keep both cooperative-discharge
    // families: hub-skewed rmat (even) and star/bipartite-hub (odd).
    for parity in 0..2u64 {
        assert!(
            seeds.iter().any(|s| (1000..2000).contains(s) && s % 2 == parity),
            "seed list lost hub family parity {parity}"
        );
    }
    // The dynamic band (>= 2000) must keep both churn families:
    // erdos-renyi (even) and genrmf (odd).
    for parity in 0..2u64 {
        assert!(
            seeds.iter().any(|s| *s >= 2000 && s % 2 == parity),
            "seed list lost dynamic family parity {parity}"
        );
    }
    // Case derivation stays deterministic run over run (the property the
    // CI cache key relies on).
    let again = sweep(&seeds);
    for (a, b) in sweep(&seeds).iter().zip(again.iter()) {
        assert_eq!(a.name, b.name);
    }
}

#[test]
fn oracle_dynamic_band_survives_churn_replay() {
    // Every dynamic-band seed replays a topology-heavy insert/delete
    // stream through the warm engine; after each batch the incremental
    // value must match a from-scratch Dinic solve of the evolved network
    // and the residual must stay a valid decomposition.
    for seed in seeds().into_iter().filter(|&s| s >= 2000) {
        run_dynamic_case(seed, 3).unwrap_or_else(|e| panic!("dynamic oracle disagreement: {e}"));
    }
}

#[test]
fn oracle_thread_oversubscription_still_agrees() {
    // A thread count far above |V| on the smallest family exercises the
    // pool's oversubscription path (workers with no vertex range) inside
    // the differential harness.
    let case = build_case(2); // washington family: tiny
    run_case(&case, 64).unwrap_or_else(|e| panic!("oversubscribed oracle run: {e}"));
}
