//! Cross-engine integration: every engine × representation must agree with
//! Dinic on a broad randomized + structured graph suite, and every result
//! must pass the max-flow/min-cut verifier.

use wbpr::graph::builder::{ArcGraph, FlowNetwork};
use wbpr::graph::{generators, Representation};
use wbpr::maxflow::{self, EngineKind, SolveOptions};

fn all_configs() -> Vec<(EngineKind, Representation)> {
    let mut v = vec![
        (EngineKind::Sequential, Representation::Rcsr),
        (EngineKind::EdmondsKarp, Representation::Rcsr),
    ];
    for kind in [EngineKind::ThreadCentric, EngineKind::VertexCentric] {
        for rep in [Representation::Rcsr, Representation::Bcsr] {
            v.push((kind, rep));
        }
    }
    v
}

fn check_suite(nets: Vec<FlowNetwork>) {
    let opts = SolveOptions { threads: 4, cycles_per_launch: 128, ..Default::default() };
    for net in nets {
        let g = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&g).value;
        for (kind, rep) in all_configs() {
            let r = maxflow::solve_arcs(&g, kind, rep, &opts);
            assert_eq!(r.value, want, "{}+{} on {}", kind.name(), rep.name(), net.name);
            maxflow::verify(&g, &r).unwrap_or_else(|e| panic!("{}+{} on {}: {e}", kind.name(), rep.name(), net.name));
        }
    }
}

#[test]
fn random_dense_and_sparse() {
    let mut nets = Vec::new();
    for seed in 0..6 {
        nets.push(generators::erdos_renyi(50, 400, 9, seed));
        nets.push(generators::erdos_renyi(120, 400, 4, seed + 100));
    }
    check_suite(nets);
}

#[test]
fn structured_generators() {
    check_suite(vec![
        generators::genrmf(&generators::GenrmfParams { a: 5, b: 5, c1: 1, c2: 50, seed: 1 }),
        generators::washington_rlg(&generators::WashingtonParams { levels: 8, width: 12, fanout: 3, max_cap: 30, seed: 2 }),
        generators::grid_road(14, 14, 0.1, 10, 3),
        generators::near_regular(300, 4, 4),
    ]);
}

#[test]
fn skewed_with_super_terminals() {
    let base = generators::rmat(&generators::RmatParams { scale: 9, edge_factor: 8, a: 0.6, b: 0.18, c: 0.18, seed: 5 });
    let net = wbpr::bench::suite::with_pairs(base, 6, 55);
    check_suite(vec![net]);
}

#[test]
fn adversarial_shapes() {
    // Zero-capacity edges, two-cycles, source/sink direct edge, dead ends.
    use wbpr::graph::Edge;
    let nets = vec![
        FlowNetwork::new(2, 0, 1, vec![Edge::new(0, 1, 7)], "direct"),
        FlowNetwork::new(3, 0, 2, vec![Edge::new(0, 1, 0), Edge::new(1, 2, 5)], "zero-cap"),
        FlowNetwork::new(
            4,
            0,
            3,
            vec![Edge::new(0, 1, 4), Edge::new(1, 2, 3), Edge::new(2, 1, 3), Edge::new(2, 3, 2), Edge::new(1, 3, 1)],
            "two-cycle",
        ),
        FlowNetwork::new(
            5,
            0,
            4,
            vec![Edge::new(0, 1, 9), Edge::new(1, 2, 9), Edge::new(0, 3, 5), Edge::new(3, 4, 1)],
            "dead-end-branch",
        ),
        FlowNetwork::new(3, 0, 2, vec![Edge::new(1, 0, 5), Edge::new(2, 1, 5)], "only-backward"),
    ];
    check_suite(nets);
}

#[test]
fn single_thread_equals_many_threads() {
    let net = generators::erdos_renyi(80, 500, 6, 42);
    let g = ArcGraph::build(&net.normalized());
    let want = maxflow::dinic::solve(&g).value;
    for threads in [1, 2, 8] {
        let opts = SolveOptions { threads, cycles_per_launch: 64, ..Default::default() };
        for kind in [EngineKind::ThreadCentric, EngineKind::VertexCentric] {
            let r = maxflow::solve_arcs(&g, kind, Representation::Bcsr, &opts);
            assert_eq!(r.value, want, "{}x{threads}", kind.name());
        }
    }
}

#[test]
fn frontier_thread_sweep_matches_dinic() {
    // The frontier AVQ path across thread counts spanning under- and
    // over-subscription, on the three regime generators the PR targets.
    let nets = vec![
        generators::rmat(&generators::RmatParams { scale: 7, edge_factor: 6, a: 0.57, b: 0.19, c: 0.19, seed: 3 }),
        generators::genrmf(&generators::GenrmfParams { a: 4, b: 4, c1: 1, c2: 40, seed: 12 }),
        generators::washington_rlg(&generators::WashingtonParams { levels: 6, width: 8, fanout: 3, max_cap: 15, seed: 13 }),
    ];
    for net in nets {
        let g = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&g).value;
        for threads in [1, 2, 8, 16] {
            let opts = SolveOptions { threads, cycles_per_launch: 64, ..Default::default() };
            for rep in [Representation::Rcsr, Representation::Bcsr] {
                let r = maxflow::solve_arcs(&g, EngineKind::VertexCentric, rep, &opts);
                assert_eq!(r.value, want, "VC+{}x{threads} on {}", rep.name(), net.name);
                maxflow::verify(&g, &r)
                    .unwrap_or_else(|e| panic!("VC+{}x{threads} on {}: {e}", rep.name(), net.name));
            }
        }
    }
}

#[test]
fn oversubscribed_pool_more_threads_than_vertices() {
    // 16 workers on a 3-vertex instance: the launch clamps to |V| active
    // workers while the rest of the pool idles — values must not change.
    use wbpr::graph::Edge;
    let net = FlowNetwork::new(3, 0, 2, vec![Edge::new(0, 1, 5), Edge::new(1, 2, 4)], "tiny3");
    let g = ArcGraph::build(&net);
    let opts = SolveOptions { threads: 16, cycles_per_launch: 32, ..Default::default() };
    for kind in [EngineKind::ThreadCentric, EngineKind::VertexCentric] {
        let r = maxflow::solve_arcs(&g, kind, Representation::Rcsr, &opts);
        assert_eq!(r.value, 4, "{} oversubscribed", kind.name());
        maxflow::verify(&g, &r).unwrap();
    }
}

#[test]
fn carried_frontier_keeps_rescans_under_15_percent() {
    // ISSUE 4 acceptance shape: with the cross-launch frontier carry-over
    // and the auto-tuned global-relabel cadence, the O(V) rescan must be
    // the exception, not the rule. Aggregate over multi-launch solves on
    // the PR's regime generators with a deliberately small launch budget
    // (many launch boundaries = many chances to rescan).
    let nets = vec![
        generators::rmat(&generators::RmatParams { scale: 8, edge_factor: 6, a: 0.57, b: 0.19, c: 0.19, seed: 31 }),
        generators::genrmf(&generators::GenrmfParams { a: 5, b: 8, c1: 1, c2: 60, seed: 32 }),
        generators::washington_rlg(&generators::WashingtonParams { levels: 12, width: 12, fanout: 3, max_cap: 30, seed: 33 }),
    ];
    let (mut launches, mut rescans) = (0u64, 0u64);
    for net in nets {
        let g = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&g).value;
        let opts = SolveOptions { threads: 4, cycles_per_launch: 8, ..Default::default() };
        let r = maxflow::solve_arcs(&g, EngineKind::VertexCentric, Representation::Bcsr, &opts);
        assert_eq!(r.value, want, "on {}", net.name);
        launches += r.stats.launches;
        rescans += r.stats.rescan_launches;
    }
    assert!(launches >= 10, "want a multi-launch workload, got {launches} launches");
    let frac = rescans as f64 / launches as f64;
    assert!(
        frac < 0.15,
        "rescan fraction {:.1}% >= 15% target ({rescans}/{launches} launches)",
        frac * 100.0
    );
}

#[test]
fn stats_reflect_work() {
    let net = generators::genrmf(&generators::GenrmfParams { a: 6, b: 6, c1: 1, c2: 40, seed: 9 });
    let g = ArcGraph::build(&net.normalized());
    let opts = SolveOptions::default();
    let r = maxflow::solve_arcs(&g, EngineKind::VertexCentric, Representation::Bcsr, &opts);
    assert!(r.stats.pushes > 0 && r.stats.relabels > 0);
    assert!(r.stats.scan_arcs >= r.stats.pushes, "every push required a scan");
    assert!(r.stats.total_ms >= r.stats.kernel_ms * 0.5);
}
