//! Sharded-session integration: TTL eviction → snapshot → re-hydration
//! round trips, consistent-hash stability, cost-based update routing, and
//! many-tenant correctness through the full coordinator stack.

use std::collections::HashMap;
use std::time::Duration;
use wbpr::coordinator::{jump_hash, Coordinator, CoordinatorConfig, Job};
use wbpr::dynamic::{GraphUpdate, UpdateBatch};
use wbpr::graph::builder::ArcGraph;
use wbpr::graph::generators;
use wbpr::maxflow::{self, SolveOptions};

fn config(shards: usize, ttl: Option<Duration>) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig {
        native_workers: 1,
        enable_device: false,
        solve: SolveOptions { threads: 2, cycles_per_launch: 64, ..Default::default() },
        ..Default::default()
    };
    cfg.session.shards = shards;
    cfg.session.ttl = ttl;
    cfg
}

/// Reference value: the session's network after `batches`, solved cold.
fn reference_value(net: &wbpr::graph::builder::FlowNetwork, batches: &[UpdateBatch]) -> i64 {
    let mut now = net.normalized();
    for b in batches {
        b.apply_to_network(&mut now).expect("valid batch");
    }
    maxflow::dinic::solve(&ArcGraph::build(&now)).value
}

#[test]
fn ttl_eviction_rehydration_roundtrip_through_coordinator() {
    // Short TTL + idle gap: every session is evicted to its on-disk
    // snapshot, then transparently re-hydrated by the next update.
    let c = Coordinator::start(config(2, Some(Duration::from_millis(20))));
    let mut nets = HashMap::new();
    for sid in 0..4u64 {
        let net = generators::erdos_renyi(40, 200, 6, 40 + sid);
        c.submit(Job::SessionOpen { session: sid, net: net.clone() });
        nets.insert(sid, net);
    }
    for o in c.collect(4) {
        o.result.expect("open ok");
    }
    // Idle long enough for several eviction ticks (tick = TTL/2, >= 5ms).
    std::thread::sleep(Duration::from_millis(250));

    let batch = |sid: u64| {
        UpdateBatch::new(vec![GraphUpdate::IncreaseCap { edge: sid as usize % 5, delta: 3 }])
    };
    let mut want = HashMap::new();
    for sid in 0..4u64 {
        let id = c.submit(Job::SessionUpdate { session: sid, batch: batch(sid) });
        want.insert(id, reference_value(&nets[&sid], &[batch(sid)]));
    }
    for o in c.collect(4) {
        let v = o.result.expect("update after eviction ok");
        assert_eq!(v.value, want[&o.id], "re-hydrated session must repair to the correct value");
    }
    let metrics = c.shutdown();
    let events = metrics.events();
    // >= 4: a slow runner may squeeze in a second evict cycle between the
    // updates and shutdown; every session was evicted at least once.
    assert!(
        events.get("session:evict").copied().unwrap_or(0) >= 4,
        "all idle sessions evicted: {events:?}"
    );
    assert_eq!(
        events.get("session:rehydrate").copied().unwrap_or(0),
        4,
        "every touched session re-hydrated exactly once: {events:?}"
    );
}

#[test]
fn eviction_preserves_value_across_close() {
    // Evicted sessions close with the snapshot's value — no rebuild.
    let c = Coordinator::start(config(1, Some(Duration::from_millis(10))));
    let net = generators::erdos_renyi(30, 150, 5, 77);
    let sid = c.open_session(net.clone());
    let open = c.recv().unwrap().result.expect("open ok");
    std::thread::sleep(Duration::from_millis(120));
    c.submit(Job::SessionClose { session: sid });
    let closed = c.recv().unwrap().result.expect("close ok");
    assert_eq!(closed.value, open.value, "close returns the evicted warm value");
    let events = c.shutdown().events();
    assert!(events.get("session:evict").copied().unwrap_or(0) >= 1, "{events:?}");
}

#[test]
fn consistent_hash_stability_across_shard_counts() {
    // The placement function is shared by every pool size; growing the
    // pool must strand only ~1/(n+1) of the id space. This is what makes
    // a rolling shard-count change safe for on-disk snapshots.
    let ids: Vec<u64> = (0..20_000u64).map(|i| i.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xD1B54A32).collect();
    for n in [2u32, 4, 8] {
        let moved = ids.iter().filter(|&&id| jump_hash(id, n) != jump_hash(id, n + 1)).count();
        let expected = ids.len() / (n as usize + 1);
        assert!(
            moved as f64 <= expected as f64 * 1.5,
            "{n}->{}: moved {moved}, expected ~{expected}",
            n + 1
        );
        // And shard choice is always in range.
        assert!(ids.iter().all(|&id| jump_hash(id, n) < n));
    }
}

#[test]
fn cost_router_recomputes_through_the_coordinator() {
    // recompute_ratio 0 forces the from-scratch leg once a cost estimate
    // exists; values must stay correct either way and the recompute must
    // be visible in the serving metrics.
    let mut cfg = config(1, None);
    cfg.router.recompute_ratio = 0.0;
    let c = Coordinator::start(cfg);
    let net = generators::erdos_renyi(40, 200, 6, 99);
    let sid = c.open_session(net.clone());
    c.recv().unwrap().result.expect("open ok");

    let b1 = UpdateBatch::new(vec![GraphUpdate::IncreaseCap { edge: 1, delta: 2 }]);
    let b2 = UpdateBatch::new(vec![GraphUpdate::DecreaseCap { edge: 3, delta: 1 }]);
    c.submit(Job::SessionUpdate { session: sid, batch: b1.clone() });
    let v1 = c.recv().unwrap().result.expect("first update ok");
    assert_eq!(v1.value, reference_value(&net, std::slice::from_ref(&b1)));
    c.submit(Job::SessionUpdate { session: sid, batch: b2.clone() });
    let v2 = c.recv().unwrap().result.expect("second update ok");
    assert_eq!(v2.value, reference_value(&net, &[b1, b2]));

    let events = c.shutdown().events();
    assert!(
        events.get("session:recompute").copied().unwrap_or(0) >= 1,
        "second batch should recompute: {events:?}"
    );
}

#[test]
fn sixty_four_sessions_across_four_shards_stay_correct() {
    // The acceptance shape (4 shards × 64 tenants), verified for
    // correctness here; throughput is the bench's job (`wbpr bench shards`).
    let c = Coordinator::start(config(4, None));
    let mut nets = HashMap::new();
    for sid in 0..64u64 {
        let net = generators::erdos_renyi(30, 140, 4 + (sid % 3) as i64, 500 + sid);
        c.submit(Job::SessionOpen { session: sid, net: net.clone() });
        nets.insert(sid, net);
    }
    for o in c.collect(64) {
        o.result.expect("open ok");
    }
    let batch = |sid: u64| {
        UpdateBatch::new(vec![
            GraphUpdate::IncreaseCap { edge: sid as usize % 7, delta: 2 },
            GraphUpdate::DecreaseCap { edge: (sid as usize + 3) % 7, delta: 1 },
        ])
    };
    let mut want = HashMap::new();
    for sid in 0..64u64 {
        let id = c.submit(Job::SessionUpdate { session: sid, batch: batch(sid) });
        want.insert(id, reference_value(&nets[&sid], &[batch(sid)]));
    }
    for o in c.collect(64) {
        let v = o.result.expect("update ok");
        assert_eq!(v.value, want[&o.id]);
    }
    for sid in 0..64u64 {
        c.submit(Job::SessionClose { session: sid });
    }
    for o in c.collect(64) {
        o.result.expect("close ok");
    }
    let metrics = c.shutdown();
    let snap = metrics.snapshot();
    assert_eq!(snap["session:open"].jobs, 64);
    assert_eq!(snap["session:update"].jobs, 64);
    assert_eq!(snap["session:close"].jobs, 64);
}
