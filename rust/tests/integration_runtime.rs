//! Runtime + device-engine integration: the AOT artifact path must produce
//! the same flows as the native engines on a shared graph suite.
//!
//! All tests skip gracefully when `make artifacts` has not been run (CI
//! without python); `make test` always builds artifacts first.

use wbpr::coordinator::device::DeviceEngine;
use wbpr::graph::builder::ArcGraph;
use wbpr::graph::{generators, Representation};
use wbpr::maxflow::{self, EngineKind, SolveOptions};
use wbpr::runtime::{Manifest, Runtime};

fn engine() -> Option<DeviceEngine> {
    match DeviceEngine::from_default_location() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
    }
}

#[test]
fn manifest_and_artifacts_consistent() {
    let Some(dir) = wbpr::runtime::find_artifacts_dir() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.variants.len() >= 3, "default build has 3 variants");
    for v in &m.variants {
        let text = std::fs::read_to_string(m.hlo_path(v)).unwrap();
        assert!(text.contains("ENTRY"), "{} lacks an entry computation", v.name);
        assert!(v.fits(v.v, v.d));
        assert!(!v.fits(v.v + 1, v.d));
    }
}

#[test]
fn device_agrees_with_all_native_engines() {
    let Some(mut eng) = engine() else { return };
    let opts = SolveOptions { threads: 2, cycles_per_launch: 64, ..Default::default() };
    for seed in 0..4u64 {
        let net = generators::erdos_renyi(36, 180, 5, seed);
        let g = ArcGraph::build(&net.normalized());
        let device = eng.solve(&g).unwrap();
        maxflow::verify(&g, &device).unwrap();
        for kind in [EngineKind::Dinic, EngineKind::Sequential, EngineKind::VertexCentric] {
            let native = maxflow::solve_arcs(&g, kind, Representation::Bcsr, &opts);
            assert_eq!(device.value, native.value, "seed {seed} vs {}", kind.name());
        }
    }
}

#[test]
fn device_handles_capacitated_graphs() {
    let Some(mut eng) = engine() else { return };
    let net = generators::washington_rlg(&generators::WashingtonParams {
        levels: 6,
        width: 8,
        fanout: 3,
        max_cap: 40,
        seed: 11,
    });
    let g = ArcGraph::build(&net.normalized());
    let want = maxflow::dinic::solve(&g).value;
    let got = eng.solve(&g).unwrap();
    assert_eq!(got.value, want);
    assert!(got.stats.launches >= 1);
    assert!(got.stats.kernel_ms > 0.0);
}

#[test]
fn variant_selection_promotes_on_degree() {
    let Some(mut rt) = Runtime::from_default_location().ok() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let small = rt.pick(32, 8).unwrap();
    let hub = rt.pick(32, 20).unwrap();
    assert!(hub.v >= small.v || hub.d > small.d, "hub degree must promote the variant");
    // Compile both and reuse from cache.
    rt.ensure_compiled(&small).unwrap();
    rt.ensure_compiled(&hub).unwrap();
    let before = rt.compile_ms;
    rt.ensure_compiled(&small).unwrap();
    assert_eq!(rt.compile_ms, before);
}

#[test]
fn device_launch_counts_scale_with_difficulty() {
    let Some(mut eng) = engine() else { return };
    // A long chain forces many launches (distance >> K cycles per launch).
    use wbpr::graph::builder::FlowNetwork;
    use wbpr::graph::Edge;
    let n = 60;
    let mut edges = Vec::new();
    for i in 0..n - 1 {
        edges.push(Edge::new(i as u32, i as u32 + 1, 2));
    }
    let net = FlowNetwork::new(n, 0, (n - 1) as u32, edges, "chain");
    let g = ArcGraph::build(&net);
    let r = eng.solve(&g).unwrap();
    assert_eq!(r.value, 2);
    maxflow::verify(&g, &r).unwrap();
}

#[test]
fn device_relabel_kernel_agrees_with_host_path() {
    let Some(mut eng) = engine() else { return };
    // Solve the same graphs with host-BFS global relabel and with the
    // device relaxation kernel; flows must agree with Dinic either way.
    for seed in 0..3u64 {
        let net = generators::erdos_renyi(36, 200, 5, seed + 40);
        let g = ArcGraph::build(&net.normalized());
        let want = maxflow::dinic::solve(&g).value;
        eng.device_relabel = false;
        let host = eng.solve(&g).unwrap();
        eng.device_relabel = true;
        let device = eng.solve(&g).unwrap();
        assert_eq!(host.value, want, "host GR seed {seed}");
        assert_eq!(device.value, want, "device GR seed {seed}");
        maxflow::verify(&g, &device).unwrap();
    }
    eng.device_relabel = false;
}

#[test]
fn device_relabel_on_structured_graph() {
    let Some(mut eng) = engine() else { return };
    eng.device_relabel = true;
    let net = generators::grid_road(8, 8, 0.1, 4, 9);
    let g = ArcGraph::build(&net.normalized());
    let want = maxflow::dinic::solve(&g).value;
    let r = eng.solve(&g).unwrap();
    assert_eq!(r.value, want);
    assert!(r.stats.global_relabels >= 1);
}

#[test]
fn failure_injection_corrupt_artifacts() {
    use std::path::Path;
    let dir = std::env::temp_dir().join(format!("wbpr-fi-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // (a) Corrupt manifest JSON.
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    // (b) Valid manifest, missing HLO file.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"abi":1,"format":"hlo-text","variants":[
            {"name":"ghost","file":"ghost.hlo.txt","kind":"flow","v":16,"d":8,"k":4,"tile":16}]}"#,
    )
    .unwrap();
    let m = Manifest::load(&dir).unwrap();
    let mut rt = Runtime::new(m).unwrap();
    let spec = rt.manifest().variants[0].clone();
    assert!(rt.ensure_compiled(&spec).is_err(), "missing HLO must fail cleanly");
    // (c) Truncated / garbage HLO text.
    std::fs::write(dir.join("ghost.hlo.txt"), "HloModule broken\nENTRY %oops {").unwrap();
    let m = Manifest::load(&dir).unwrap();
    let mut rt = Runtime::new(m).unwrap();
    let spec = rt.manifest().variants[0].clone();
    assert!(rt.ensure_compiled(&spec).is_err(), "garbage HLO must fail cleanly");
    // (d) Unknown kind is rejected at parse time.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"abi":1,"format":"hlo-text","variants":[
            {"name":"x","file":"x","kind":"quantum","v":1,"d":1,"k":1,"tile":1}]}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err());
    let _ = std::fs::remove_dir_all(Path::new(&dir));
}

#[test]
fn mincut_certificate_from_device_flow() {
    let Some(mut eng) = engine() else { return };
    let net = generators::erdos_renyi(32, 160, 5, 13);
    let g = ArcGraph::build(&net.normalized());
    let r = eng.solve(&g).unwrap();
    let cut = maxflow::mincut::extract(&g, &r);
    maxflow::mincut::validate(&g, &r, &cut).unwrap();
}
