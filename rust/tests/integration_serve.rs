//! Wire-serving integration: a real `NetServer` on a loopback socket,
//! exercised with the blocking [`Client`] and with raw pipelined frames —
//! session lifecycle values must match the in-process coordinator path,
//! overload must answer `Overloaded` (both shed flavors), and malformed
//! bytes must produce an error frame, never a crash.

use std::net::TcpStream;
use wbpr::coordinator::wire::{self, Request, Response};
use wbpr::coordinator::{Client, CoordinatorConfig, NetServer, ShardPoolConfig};
use wbpr::dynamic::{GraphUpdate, UpdateBatch};
use wbpr::graph::builder::{ArcGraph, FlowNetwork};
use wbpr::graph::generators;
use wbpr::maxflow::{self, SolveOptions};

fn config(shards: usize, queue_bound: usize, deadline_ms: Option<u64>) -> CoordinatorConfig {
    CoordinatorConfig {
        native_workers: 1,
        enable_device: false,
        solve: SolveOptions { threads: 2, cycles_per_launch: 64, ..Default::default() },
        session: ShardPoolConfig {
            shards,
            queue_bound,
            queue_deadline: deadline_ms.map(std::time::Duration::from_millis),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Reference value: the session's network after `batches`, solved cold.
fn reference_value(net: &FlowNetwork, batches: &[UpdateBatch]) -> i64 {
    let mut now = net.normalized();
    for b in batches {
        b.apply_to_network(&mut now).expect("valid batch");
    }
    maxflow::dinic::solve(&ArcGraph::build(&now)).value
}

fn value_of(resp: Response) -> i64 {
    match resp {
        Response::Value { value, .. } => value,
        other => panic!("expected Value, got {other:?}"),
    }
}

#[test]
fn session_lifecycle_over_the_socket_matches_in_process_values() {
    let server = NetServer::start("127.0.0.1:0", config(2, 0, None)).expect("bind");
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");

    assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);

    // Open: the response carries the initial solve value.
    let net = generators::erdos_renyi(40, 200, 6, 5);
    let opened = client.call(&Request::Open { session: 7, net: net.clone() }).unwrap();
    assert_eq!(value_of(opened), reference_value(&net, &[]));

    // Update: repaired value must match a cold re-solve of the edited net.
    let batch = UpdateBatch::new(vec![GraphUpdate::IncreaseCap { edge: 0, delta: 4 }]);
    let updated =
        client.call(&Request::Update { session: 7, batch: batch.clone() }).unwrap();
    let want = reference_value(&net, &[batch]);
    assert_eq!(value_of(updated), want);

    // Close returns the session's last value.
    let closed = client.call(&Request::Close { session: 7 }).unwrap();
    assert_eq!(value_of(closed), want);

    // One-shot solve goes through the same front door.
    let one = generators::erdos_renyi(30, 150, 5, 77);
    let solved = client.call(&Request::Solve { net: one.clone() }).unwrap();
    assert_eq!(value_of(solved), reference_value(&one, &[]));

    // Reserved session ids fail soft with an Error frame, not a panic.
    let reserved = client.call(&Request::Open { session: 1 << 63, net: one }).unwrap();
    assert!(matches!(reserved, Response::Error { .. }), "{reserved:?}");

    assert_eq!(client.call(&Request::Shutdown).unwrap(), Response::Pong);
    let metrics = server.wait();
    let events = metrics.events();
    assert!(events.get("serve:requests").copied().unwrap_or(0) >= 6, "{events:?}");
    assert!(events.get("serve:connections").copied().unwrap_or(0) >= 1, "{events:?}");
}

#[test]
fn shed_under_load_answers_overloaded_and_counts_it() {
    // One shard with a queue bound of 1: a pipelined burst must come back
    // partly as Overloaded frames (immediate shed), visible in the
    // metrics and the Prometheus rendering.
    let server = NetServer::start("127.0.0.1:0", config(1, 1, None)).expect("bind");
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");

    let net = generators::erdos_renyi(400, 3000, 8, 11);
    let opened = client.call(&Request::Open { session: 1, net }).unwrap();
    assert!(matches!(opened, Response::Value { .. }), "{opened:?}");

    // Raw pipelining: write the whole burst without reading, so requests
    // pile up behind the single session worker faster than it drains.
    let mut writer = TcpStream::connect(&addr).expect("connect burst");
    let mut reader = writer.try_clone().expect("clone");
    let total = 64u64;
    for i in 0..total {
        let batch = UpdateBatch::new(vec![GraphUpdate::IncreaseCap {
            edge: i as usize % 100,
            delta: 1,
        }]);
        wire::write_request(&mut writer, i + 1, &Request::Update { session: 1, batch })
            .expect("write burst frame");
    }
    let (mut ok, mut overloaded) = (0u64, 0u64);
    for _ in 0..total {
        match wire::read_response(&mut reader).expect("burst response").1 {
            Response::Value { .. } => ok += 1,
            Response::Overloaded { msg } => {
                assert!(msg.starts_with("overloaded"), "{msg}");
                overloaded += 1;
            }
            other => panic!("unexpected burst response: {other:?}"),
        }
    }
    assert_eq!(ok + overloaded, total);
    assert!(ok >= 1, "at least the head of the burst is admitted");
    assert!(overloaded >= 1, "a bound-1 queue must shed most of a 64-deep burst");

    assert_eq!(client.call(&Request::Shutdown).unwrap(), Response::Pong);
    let metrics = server.wait();
    let events = metrics.events();
    assert_eq!(events.get("serve:shed").copied().unwrap_or(0), overloaded, "{events:?}");
    let prom = metrics.render_prometheus();
    assert!(prom.contains("wbpr_events_total{event=\"serve:shed\"}"), "{prom}");
}

#[test]
fn queue_deadline_sheds_stale_entries_as_overloaded() {
    // Queue-with-deadline flavor: the burst is *admitted* (bound 1 no
    // longer sheds at the door) but entries that wait past 1ms are shed
    // by the shard worker at dequeue time, completing as Overloaded.
    // Forcing the recompute leg makes every drained update cost a full
    // solve, so the 1ms deadline reliably expires down the queue.
    let mut cfg = config(1, 1, Some(1));
    cfg.router.recompute_ratio = 0.0;
    let server = NetServer::start("127.0.0.1:0", cfg).expect("bind");
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");

    let net = generators::erdos_renyi(400, 3000, 8, 13);
    let opened = client.call(&Request::Open { session: 1, net }).unwrap();
    assert!(matches!(opened, Response::Value { .. }), "{opened:?}");

    let mut writer = TcpStream::connect(&addr).expect("connect burst");
    let mut reader = writer.try_clone().expect("clone");
    let total = 64u64;
    for i in 0..total {
        let batch = UpdateBatch::new(vec![
            GraphUpdate::IncreaseCap { edge: i as usize % 100, delta: 2 },
            GraphUpdate::DecreaseCap { edge: (i as usize + 7) % 100, delta: 1 },
        ]);
        wire::write_request(&mut writer, i + 1, &Request::Update { session: 1, batch })
            .expect("write burst frame");
    }
    let (mut ok, mut overloaded) = (0u64, 0u64);
    for _ in 0..total {
        match wire::read_response(&mut reader).expect("burst response").1 {
            Response::Value { .. } => ok += 1,
            Response::Overloaded { msg } => {
                assert!(msg.contains("deadline"), "deadline sheds name the cause: {msg}");
                overloaded += 1;
            }
            other => panic!("unexpected burst response: {other:?}"),
        }
    }
    assert_eq!(ok + overloaded, total);
    assert!(overloaded >= 1, "a 1ms deadline must shed part of a 64-deep burst");

    assert_eq!(client.call(&Request::Shutdown).unwrap(), Response::Pong);
    let events = server.wait().events();
    assert_eq!(events.get("serve:deadline_shed").copied().unwrap_or(0), overloaded, "{events:?}");
    assert_eq!(events.get("serve:shed").copied().unwrap_or(0), 0, "no front-door sheds");
}

#[test]
fn malformed_bytes_get_an_error_frame_not_a_crash() {
    let server = NetServer::start("127.0.0.1:0", config(1, 0, None)).expect("bind");
    let addr = server.addr().to_string();

    // Garbage that can never be a valid header: the server must answer
    // with a protocol-error frame (req_id 0) and close the connection.
    let mut stream = TcpStream::connect(&addr).expect("connect");
    use std::io::Write as _;
    stream.write_all(&[0xDE; 64]).expect("write garbage");
    let (req_id, resp) = wire::read_response(&mut stream).expect("error frame");
    assert_eq!(req_id, 0, "protocol errors correlate to no request");
    assert!(matches!(resp, Response::Error { .. }), "{resp:?}");

    // The server survives: a fresh, well-formed connection still works.
    let mut client = Client::connect(&addr).expect("connect after garbage");
    assert_eq!(client.call(&Request::Ping).unwrap(), Response::Pong);
    assert_eq!(client.call(&Request::Shutdown).unwrap(), Response::Pong);
    let events = server.wait().events();
    assert!(events.get("serve:bad_frame").copied().unwrap_or(0) >= 1, "{events:?}");
}
