//! Dynamic-subsystem integration: streaming updates through the engine
//! and the coordinator, plus the PR's acceptance criterion — on a
//! 1%-of-|E| capacity-update batch the incremental repair must reach the
//! same verified max-flow value as a from-scratch solve at a 5x+ lower
//! `pushes + relabels` cost than the from-scratch VC recompute.

use wbpr::coordinator::{Coordinator, CoordinatorConfig, Job};
use wbpr::dynamic::{DynamicFlow, GraphUpdate, UpdateBatch};
use wbpr::graph::builder::ArcGraph;
use wbpr::graph::generators::{self, update_stream, UpdateStreamParams};
use wbpr::graph::Representation;
use wbpr::maxflow::{self, EngineKind, SolveOptions};

fn opts() -> SolveOptions {
    SolveOptions { threads: 2, cycles_per_launch: 128, ..Default::default() }
}

#[test]
fn one_percent_batch_is_5x_cheaper_than_scratch_vc() {
    // The acceptance graph: a generated mesh with a wide capacity range
    // (the regime where repair locality pays off and from-scratch solves
    // do real work). One worker thread so the push/relabel counters on
    // both sides are deterministic — the 5x margin must not depend on
    // lock-free race interleavings.
    let opts = SolveOptions { threads: 1, cycles_per_launch: 128, ..Default::default() };
    let net = generators::genrmf(&generators::GenrmfParams { a: 6, b: 10, c1: 1, c2: 100, seed: 77 });
    let mut df = DynamicFlow::new(&net, &opts);
    let stream = update_stream(
        df.network(),
        &UpdateStreamParams::capacity_only(df.network().m(), 3, 0.01, 30, 0xACCE),
    );
    assert!(stream.batches[0].len() >= 10, "1% of |E| must be a real batch");
    for batch in &stream.batches {
        let report = df.apply(batch).expect("valid stream");
        // Same verified value as a from-scratch solve...
        let now = df.network().clone();
        let scratch = maxflow::solve(&now, EngineKind::VertexCentric, Representation::Bcsr, &opts);
        assert_eq!(report.value, scratch.value, "incremental value differs from scratch VC");
        let dinic = maxflow::dinic::solve(&ArcGraph::build(&now.normalized()));
        assert_eq!(report.value, dinic.value, "incremental value differs from Dinic");
        maxflow::verify(df.arcs(), &df.flow_result()).expect("incremental flow verifies");
        // ... at >= 5x less push/relabel work than the VC recompute.
        let inc_ops = report.stats.pushes + report.stats.relabels;
        let scratch_ops = scratch.stats.pushes + scratch.stats.relabels;
        assert!(
            inc_ops * 5 <= scratch_ops,
            "repair not 5x cheaper: incremental {inc_ops} vs scratch {scratch_ops}"
        );
    }
}

#[test]
fn mixed_topology_stream_stays_verified() {
    let net = generators::erdos_renyi(120, 700, 10, 5);
    let mut df = DynamicFlow::new(&net, &opts());
    let stream = update_stream(
        df.network(),
        &UpdateStreamParams {
            batches: 6,
            batch_size: 8,
            p_increase: 0.35,
            p_decrease: 0.35,
            p_insert: 0.15,
            max_delta: 6,
            seed: 99,
        },
    );
    for batch in &stream.batches {
        let report = df.apply(batch).expect("valid stream");
        let dinic = maxflow::dinic::solve(&ArcGraph::build(&df.network().normalized()));
        assert_eq!(report.value, dinic.value);
        maxflow::verify(df.arcs(), &df.flow_result()).unwrap();
    }
    assert_eq!(df.batches(), 6);
}

#[test]
fn warm_session_serves_update_stream_through_coordinator() {
    let config = CoordinatorConfig {
        native_workers: 1,
        enable_device: false,
        solve: opts(),
        ..Default::default()
    };
    let coord = Coordinator::start(config);
    let net = generators::washington_rlg(&generators::WashingtonParams {
        levels: 10,
        width: 10,
        fanout: 3,
        max_cap: 20,
        seed: 13,
    });
    let sid = coord.open_session(net.clone());
    let open = coord.recv().unwrap().result.expect("open ok");
    let want0 = maxflow::dinic::solve(&ArcGraph::build(&net.normalized())).value;
    assert_eq!(open.value, want0);

    // Stream three batches; values must track a from-scratch oracle that
    // replays the same updates.
    let stream = update_stream(&net.normalized(), &UpdateStreamParams::capacity_only(net.m(), 3, 0.02, 10, 4242));
    let mut oracle = DynamicFlow::new(&net, &opts());
    for batch in &stream.batches {
        let want = oracle.apply(batch).unwrap().value;
        coord.submit(Job::SessionUpdate { session: sid, batch: batch.clone() });
        let got = coord.recv().unwrap().result.expect("update ok");
        assert_eq!(got.value, want, "coordinator session tracks the oracle");
    }
    coord.submit(Job::SessionClose { session: sid });
    let closed = coord.recv().unwrap().result.expect("close ok");
    assert_eq!(closed.value, oracle.value());
    coord.shutdown();
}

#[test]
fn tombstone_regrow_through_updates() {
    // Delete every edge on the only path, then regrow via increases.
    use wbpr::graph::builder::FlowNetwork;
    use wbpr::graph::Edge;
    let net = FlowNetwork::new(3, 0, 2, vec![Edge::new(0, 1, 4), Edge::new(1, 2, 4)], "line3");
    let mut df = DynamicFlow::new(&net, &opts());
    assert_eq!(df.value(), 4);
    df.apply(&UpdateBatch::new(vec![GraphUpdate::DeleteEdge { edge: 0 }])).unwrap();
    assert_eq!(df.value(), 0);
    df.apply(&UpdateBatch::new(vec![GraphUpdate::IncreaseCap { edge: 0, delta: 2 }])).unwrap();
    assert_eq!(df.value(), 2);
    maxflow::verify(df.arcs(), &df.flow_result()).unwrap();
}
