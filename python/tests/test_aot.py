"""AOT path: lowering to HLO text must produce parseable, entry-complete
modules with the expected parameter/result shapes (the rust runtime's ABI)."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_hlo():
    return aot.lower_variant(v=16, d=8, k=4, tile=16)


def test_hlo_text_has_entry(small_hlo):
    assert "ENTRY" in small_hlo
    assert "HloModule" in small_hlo


def test_hlo_text_shapes(small_hlo):
    # 8 parameters with the ABI shapes (donated carry still appears as
    # parameters in HLO).
    assert "s32[16,8]" in small_hlo  # nbr/rev
    assert "f32[16,8]" in small_hlo  # mask/cf
    assert "f32[16]" in small_hlo    # e/excl
    assert "s32[16]" in small_hlo    # h
    assert "s32[1]" in small_hlo     # nreal / active count


def test_hlo_is_deterministic():
    a = aot.lower_variant(v=16, d=8, k=4, tile=16)
    b = aot.lower_variant(v=16, d=8, k=4, tile=16)
    assert a == b


def test_manifest_writer(tmp_path):
    out = tmp_path / "artifacts"
    cmd = [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--variants", "16x8x4"]
    env = dict(os.environ)
    subprocess.run(cmd, check=True, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), env=env)
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    # Each (V, D, K) spec yields a flow variant and a relabel variant.
    assert len(manifest["variants"]) == 2
    names = {v["name"]: v for v in manifest["variants"]}
    assert set(names) == {"wbpr_v16_d8_k4", "wbpr_gr_v16_d8_k4"}
    for v in names.values():
        assert (out / v["file"]).exists()
        assert v["v"] == 16 and v["d"] == 8 and v["k"] == 4
    assert names["wbpr_v16_d8_k4"]["kind"] == "flow"
    assert names["wbpr_gr_v16_d8_k4"]["kind"] == "relabel"
