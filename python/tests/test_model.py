"""L2 correctness: the K-cycle device program — step/ref equivalence,
invariant preservation, and end-to-end convergence to the true max-flow on
whole (packed) graphs."""

import random

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from tests.util import dinic, random_graph, random_state


def pack_random(seed, n, m, V, D, max_cap=6):
    rng = random.Random(seed)
    while True:
        edges = random_graph(rng, n, m, max_cap)
        # Need positive flow between 0 and n-1 for an interesting test.
        if dinic(n, edges, 0, n - 1) > 0:
            return edges, model.pack_graph(n, edges, 0, n - 1, V, D)


def test_pack_graph_layout():
    edges = [(0, 1, 3), (1, 2, 2), (0, 2, 1)]
    nbr, rev, mask, cf, e, h, excl, nreal = model.pack_graph(3, edges, 0, 2, 4, 4)
    assert nbr.shape == (4, 4)
    # Vertex 0: out-arcs to 1 and 2; vertex 1: reverse of (0,1) + forward (1,2).
    assert float(cf[0, 0]) == 3.0 and int(nbr[0, 0]) == 1
    assert float(mask.sum()) == 6.0  # 3 edges * 2 slots
    # rev is an involution over real slots.
    rev_np = np.asarray(rev).reshape(-1)
    mask_np = np.asarray(mask).reshape(-1)
    for flat, m in enumerate(mask_np):
        if m > 0:
            assert rev_np[rev_np[flat]] == flat
    assert int(h[0]) == 3 and float(excl[0]) == 1.0 and float(excl[2]) == 1.0


def test_preflow_saturates_source():
    edges = [(0, 1, 3), (1, 2, 2), (0, 2, 1)]
    nbr, rev, mask, cf, e, h, excl, nreal = model.pack_graph(3, edges, 0, 2, 4, 4)
    cf2, e2, total = model.preflow(nbr, mask, cf, rev, e, 0)
    assert total == 4.0
    assert float(e2[1]) == 3.0 and float(e2[2]) == 1.0
    # Source rows zeroed, reverse slots credited.
    assert float(cf2[0].sum()) == 0.0
    np.testing.assert_allclose(np.asarray(cf2).sum(), np.asarray(cf).sum())


def step_invariants(nbr, rev, mask, cf, e, h, excl, nreal, steps=20):
    """cf >= 0, e >= 0, total (cf+e) conserved across steps."""
    total0 = float(jnp.sum(cf * mask)) + 0  # capacity mass
    for _ in range(steps):
        cf, e, h = ref.step(nbr, rev, mask, cf, e, h, excl, nreal)
        cf_np, e_np = np.asarray(cf), np.asarray(e)
        assert (cf_np >= -1e-6).all(), "negative residual"
        assert (e_np >= -1e-6).all(), "negative excess"
        assert abs(float((cf * mask).sum()) - total0) < 1e-3, "capacity mass not conserved"
    return cf, e, h


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_invariants_on_random_graphs(seed):
    edges, state = pack_random(seed, 8, 20, 8, 8)
    nbr, rev, mask, cf, e, h, excl, nreal = state
    cf, e, total = model.preflow(nbr, mask, cf, rev, e, 0)
    step_invariants(nbr, rev, mask, cf, e, h, excl, nreal)


@pytest.mark.parametrize("seed", range(6))
def test_device_program_converges_to_maxflow(seed):
    """Run the full device loop (no global relabel — heights saturate on
    their own) until quiescent; e(t) must equal Dinic's max flow."""
    n, m, V, D = 10, 26, 16, 16
    edges, state = pack_random(seed, n, m, V, D)
    want = dinic(n, edges, 0, n - 1)
    nbr, rev, mask, cf, e, h, excl, nreal = state
    cf, e, _ = model.preflow(nbr, mask, cf, rev, e, 0)
    for _ in range(200):
        cf, e, h, count = model.run_cycles(nbr, rev, mask, cf, e, h, excl, nreal, cycles=8, tile=V)
        if int(count[0]) == 0:
            break
    assert int(count[0]) == 0, "did not quiesce"
    assert float(e[n - 1]) == float(want), f"flow mismatch: {float(e[n-1])} vs {want}"


def test_run_cycles_matches_ref_twin():
    rng = random.Random(11)
    state = random_state(rng, 16, 8, 15)
    nbr, mask, cf, e, h, excl, nreal = state
    rev = jnp.array(np.random.default_rng(1).permutation(16 * 8).reshape(16, 8), jnp.int32)
    # rev here is arbitrary (not an involution): both paths must still
    # compute the same function of their inputs.
    a = model.run_cycles(nbr, rev, mask, cf, e, h, excl, nreal, cycles=5, tile=16)
    b = model.run_cycles_ref(nbr, rev, mask, cf, e, h, excl, nreal, cycles=5)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=0, atol=0)


def test_active_count_counts():
    edges = [(0, 1, 3), (1, 2, 2)]
    nbr, rev, mask, cf, e, h, excl, nreal = model.pack_graph(3, edges, 0, 2, 4, 4)
    cf, e, _ = model.preflow(nbr, mask, cf, rev, e, 0)
    # Vertex 1 now has excess and a residual arc: exactly one active vertex.
    assert int(ref.active_count(cf, e, h, excl, nreal[0], mask)) == 1


def test_multi_source_rejects_oversize():
    with pytest.raises(AssertionError):
        model.pack_graph(10, [], 0, 9, 8, 4)


def test_run_relabel_matches_ref_twin_and_converges():
    edges = [(0, 1, 2), (1, 2, 2), (2, 3, 2), (1, 3, 1)]
    nbr, rev, mask, cf, e, h, excl, nreal = model.pack_graph(4, edges, 0, 3, 4, 4)
    dist = jnp.where(jnp.arange(4) == 3, 0, 1 << 30).astype(jnp.int32)
    a_dist, a_changed = model.run_relabel(nbr, mask, cf, dist, cycles=6, tile=4)
    b_dist, b_changed = model.run_relabel_ref(nbr, mask, cf, dist, cycles=6)
    np.testing.assert_array_equal(np.asarray(a_dist), np.asarray(b_dist))
    assert int(a_changed[0]) == int(b_changed[0])
    # Fixpoint: BFS distances to the sink along residual (= original,
    # preflow not applied) arcs: 3 at 0; 1,2 adjacent; 0 via 1.
    np.testing.assert_array_equal(np.asarray(a_dist)[:4], [2, 1, 1, 0])
    # A second round reports zero changes (fixpoint certificate).
    c_dist, c_changed = model.run_relabel(nbr, mask, cf, a_dist, cycles=4, tile=4)
    assert int(c_changed[0]) == 0
    np.testing.assert_array_equal(np.asarray(c_dist), np.asarray(a_dist))
