"""L1 correctness: the Pallas proposal kernel vs the pure-jnp oracle
(ref.proposals), swept over shapes, tilings and arbitrary states."""

import random

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import push_relabel, ref
from tests.util import random_state


def assert_proposals_match(state, tile):
    nbr, mask, cf, e, h, excl, nreal = state
    dk, jk, hk = push_relabel.proposals(nbr, mask, cf, e, h, excl, nreal, tile=tile)
    dr, jr, hr = ref.proposals(nbr, mask, cf, e, h, excl, nreal[0])
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), err_msg="push amounts")
    np.testing.assert_array_equal(np.asarray(jk), np.asarray(jr), err_msg="chosen slots")
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr), err_msg="new heights")


@pytest.mark.parametrize("V,D,tile", [(8, 4, 0), (16, 8, 8), (32, 8, 16), (64, 8, 64), (64, 16, 32)])
def test_kernel_matches_ref_random_states(V, D, tile):
    rng = random.Random(V * 1000 + D)
    for _ in range(5):
        assert_proposals_match(random_state(rng, V, D, V - 1 if V > 2 else V), tile)


@settings(max_examples=40, deadline=None)
@given(
    v_exp=st.integers(min_value=2, max_value=6),
    d_exp=st.integers(min_value=1, max_value=4),
    tile_div=st.integers(min_value=0, max_value=2),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_kernel_matches_ref_hypothesis(v_exp, d_exp, tile_div, seed):
    V, D = 1 << v_exp, 1 << d_exp
    tile = 0 if tile_div == 0 else max(1, V >> tile_div)
    rng = random.Random(seed)
    assert_proposals_match(random_state(rng, V, D, max(V - 1, 2)), tile)


def test_tiling_is_invisible():
    rng = random.Random(7)
    state = random_state(rng, 64, 8, 63)
    nbr, mask, cf, e, h, excl, nreal = state
    outs = []
    for tile in (64, 32, 16, 8):
        outs.append(push_relabel.proposals(nbr, mask, cf, e, h, excl, nreal, tile=tile))
    for o in outs[1:]:
        for a, b in zip(outs[0], o):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_inactive_vertices_produce_nothing():
    V, D = 8, 4
    nbr = jnp.zeros((V, D), jnp.int32)
    mask = jnp.ones((V, D), jnp.float32)
    cf = jnp.ones((V, D), jnp.float32)
    e = jnp.zeros((V,), jnp.float32)  # no excess anywhere
    h = jnp.zeros((V,), jnp.int32)
    excl = jnp.zeros((V,), jnp.float32)
    n = jnp.array([V], jnp.int32)
    d, j, newh = push_relabel.proposals(nbr, mask, cf, e, h, excl, n)
    assert np.all(np.asarray(d) == 0)
    assert np.all(np.asarray(j) == -1)
    np.testing.assert_array_equal(np.asarray(newh), np.asarray(h))


def test_excluded_vertices_never_act():
    V, D = 8, 4
    rng = random.Random(3)
    nbr, mask, cf, e, h, excl, nreal = random_state(rng, V, D, V - 1)
    e = e.at[:].set(5.0)  # everyone has excess
    d, j, newh = push_relabel.proposals(nbr, mask, cf, e, h, excl, nreal)
    excl_np = np.asarray(excl) > 0
    assert np.all(np.asarray(d)[excl_np] == 0)
    assert np.all(np.asarray(j)[excl_np] == -1)
    np.testing.assert_array_equal(np.asarray(newh)[excl_np], np.asarray(h)[excl_np])


def test_dead_end_vertex_is_lifted():
    # Excess but no residual arcs -> relabeled past n (deactivated).
    V, D = 4, 2
    nbr = jnp.zeros((V, D), jnp.int32)
    mask = jnp.zeros((V, D), jnp.float32)
    cf = jnp.zeros((V, D), jnp.float32)
    e = jnp.array([0, 3, 0, 0], jnp.float32)
    h = jnp.zeros((V,), jnp.int32)
    excl = jnp.array([1, 0, 0, 1], jnp.float32)
    n = jnp.array([4], jnp.int32)
    _, _, newh = push_relabel.proposals(nbr, mask, cf, e, h, excl, n)
    assert int(np.asarray(newh)[1]) == 5


def test_min_reduce_micro_kernel():
    rng = np.random.default_rng(5)
    x = jnp.array(rng.integers(0, 100, (32, 16)), jnp.int32)
    m = jnp.array(rng.random((32, 16)) < 0.5, jnp.float32)
    got = push_relabel.masked_min_rows(x, m, tile=16)
    want = np.where(np.asarray(m) > 0, np.asarray(x), int(push_relabel.BIG)).min(axis=1)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_vmem_budget_within_tpu_limits():
    # DESIGN.md §9: the largest default variant must fit VMEM comfortably.
    assert push_relabel.vmem_bytes(1024, 32) < 4 * 1024 * 1024


def test_relabel_kernel_matches_ref():
    rng = random.Random(21)
    for tile in (0, 16, 8):
        nbr, mask, cf, _, _, _, _ = random_state(rng, 32, 8, 31)
        dist = jnp.where(jnp.arange(32) == 5, 0, 1 << 30).astype(jnp.int32)
        got, gc = push_relabel.relabel_step(nbr, mask, cf, dist, tile=tile)
        want, wc = ref.relabel_step(nbr, mask, cf, dist)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(gc) == int(wc)


def test_relabel_fixpoint_is_bfs_distance():
    # Chain 0<-1<-2<-3 via residual arcs: dist from vertex 0.
    V, D = 4, 2
    nbr = jnp.array([[0, 0], [0, 0], [1, 0], [2, 0]], jnp.int32)
    mask = jnp.array([[0, 0], [1, 0], [1, 0], [1, 0]], jnp.float32)
    cf = mask * 1.0
    dist = jnp.array([0, 1 << 30, 1 << 30, 1 << 30], jnp.int32)
    out = ref.relabel_fixpoint(nbr, mask, cf, dist)
    np.testing.assert_array_equal(np.asarray(out), [0, 1, 2, 3])


def test_relabel_ignores_saturated_arcs():
    V, D = 3, 1
    nbr = jnp.array([[0], [0], [1]], jnp.int32)
    mask = jnp.ones((V, D), jnp.float32)
    cf = jnp.array([[0.0], [0.0], [1.0]], jnp.float32)  # 1->0 saturated
    dist = jnp.array([0, 1 << 30, 1 << 30], jnp.int32)
    out = ref.relabel_fixpoint(nbr, mask, cf, dist)
    assert int(out[1]) >= (1 << 30)  # unreachable through saturated arc
    assert int(out[2]) >= (1 << 30)  # transitively unreachable
