"""Shared helpers for the python test-suite: random graph/state generation
and a tiny Dinic oracle (independent of the rust implementation)."""

import random
from collections import deque

import jax.numpy as jnp


def random_graph(rng, n, m, max_cap=9):
    """Random directed capacitated graph without self loops / duplicates."""
    seen = set()
    edges = []
    for _ in range(m):
        u = rng.randrange(n)
        v = rng.randrange(n)
        if u == v or (u, v) in seen:
            continue
        seen.add((u, v))
        edges.append((u, v, rng.randint(1, max_cap)))
    return edges


def dinic(n, edges, s, t):
    """Reference max-flow (pure python)."""
    to, cap, nxt, head = [], [], [], [-1] * n

    def add(u, v, c):
        to.append(v)
        cap.append(c)
        nxt.append(head[u])
        head[u] = len(to) - 1

    for u, v, c in edges:
        add(u, v, c)
        add(v, u, 0)

    flow = 0
    while True:
        level = [-1] * n
        level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            a = head[u]
            while a != -1:
                if cap[a] > 0 and level[to[a]] < 0:
                    level[to[a]] = level[u] + 1
                    q.append(to[a])
                a = nxt[a]
        if level[t] < 0:
            return flow
        it = list(head)

        def dfs(u, lim):
            if u == t:
                return lim
            while it[u] != -1:
                a = it[u]
                v = to[a]
                if cap[a] > 0 and level[v] == level[u] + 1:
                    d = dfs(v, min(lim, cap[a]))
                    if d > 0:
                        cap[a] -= d
                        cap[a ^ 1] += d
                        return d
                it[u] = nxt[a]
            return 0

        while True:
            f = dfs(s, float("inf"))
            if f == 0:
                break
            flow += f


def random_state(rng, V, D, nreal):
    """An arbitrary (not necessarily reachable) device state — the kernel
    must agree with the reference on *any* well-formed input."""
    nbr = [[rng.randrange(nreal) for _ in range(D)] for _ in range(V)]
    mask = [[1.0 if rng.random() < 0.7 else 0.0 for _ in range(D)] for _ in range(V)]
    cf = [[float(rng.randint(0, 5)) for _ in range(D)] for _ in range(V)]
    e = [float(rng.randint(0, 4)) for _ in range(V)]
    h = [rng.randrange(nreal + 2) for _ in range(V)]
    excl = [0.0] * V
    excl[0] = 1.0
    excl[nreal - 1] = 1.0
    return (
        jnp.array(nbr, dtype=jnp.int32),
        jnp.array(mask, dtype=jnp.float32),
        jnp.array(cf, dtype=jnp.float32),
        jnp.array(e, dtype=jnp.float32),
        jnp.array(h, dtype=jnp.int32),
        jnp.array(excl, dtype=jnp.float32),
        jnp.array([nreal], dtype=jnp.int32),
    )
