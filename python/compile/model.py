"""L2 — the device-side push-relabel program: K bulk-synchronous cycles of
(L1 Pallas proposals -> XLA scatter combine), plus the active-vertex count
for the host's early exit. This is what `aot.py` lowers to HLO text and the
rust runtime executes between global relabels (Alg. 1's GPU step).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import push_relabel, ref


def _combine(nbr, rev, cf, e, d, j, newh):
    """Apply proposals: the deterministic scatter form of Alg. 1's atomic
    push updates (see ref.apply_proposals for the spec)."""
    return ref.apply_proposals(nbr, rev, cf, e, d, j, newh)


def step(nbr, rev, mask, cf, e, h, excl, nreal, *, tile=0):
    """One device cycle: Pallas proposals + scatter combine."""
    d, j, newh = push_relabel.proposals(nbr, mask, cf, e, h, excl, nreal, tile=tile)
    return _combine(nbr, rev, cf, e, d, j, newh)


@functools.partial(jax.jit, static_argnames=("cycles", "tile"))
def run_cycles(nbr, rev, mask, cf, e, h, excl, nreal, *, cycles, tile=0):
    """`cycles` device iterations + the remaining-active count.

    Inputs/outputs follow the ABI of DESIGN.md §7; `nbr`/`rev`/`mask` are
    loop-invariant (packed once by the rust coordinator), the (cf, e, h)
    carry is donated on the AOT path.
    """

    def body(_, state):
        cf, e, h = state
        return step(nbr, rev, mask, cf, e, h, excl, nreal, tile=tile)

    cf, e, h = jax.lax.fori_loop(0, cycles, body, (cf, e, h))
    count = ref.active_count(cf, e, h, excl, nreal, mask)
    return cf, e, h, jnp.reshape(count, (1,))


def run_cycles_ref(nbr, rev, mask, cf, e, h, excl, nreal, *, cycles):
    """Pure-jnp twin of run_cycles (differential testing)."""
    cf, e, h = ref.run_cycles(nbr, rev, mask, cf, e, h, excl, nreal, cycles)
    count = ref.active_count(cf, e, h, excl, nreal, mask)
    return cf, e, h, jnp.reshape(count, (1,))


@functools.partial(jax.jit, static_argnames=("cycles", "tile"))
def run_relabel(nbr, mask, cf, dist, *, cycles, tile=0):
    """`cycles` global-relabel relaxation sweeps + total-change count
    (device-side GlobalRelabel; the host loops launches until the count
    is 0, which certifies the BFS fixpoint)."""

    def body(_, state):
        dist, changed = state
        dist, c = push_relabel.relabel_step(nbr, mask, cf, dist, tile=tile)
        return dist, changed + c

    dist, changed = jax.lax.fori_loop(0, cycles, body, (dist, jnp.int32(0)))
    return dist, jnp.reshape(changed, (1,))


def run_relabel_ref(nbr, mask, cf, dist, *, cycles):
    """Pure-jnp twin of run_relabel."""
    total = 0
    for _ in range(cycles):
        dist, c = ref.relabel_step(nbr, mask, cf, dist)
        total += int(c)
    return dist, jnp.array([total], jnp.int32)


# ---------------------------------------------------------------------------
# Packing helpers (the python mirror of the rust runtime's packer; used by
# the python tests to drive whole graphs through the device program).
# ---------------------------------------------------------------------------


def pack_graph(n, edges, s, t, V, D):
    """Pack a directed capacitated edge list into the padded device layout.

    `edges` = [(u, v, cap)], arc pairing as in the rust arena: edge i gives
    forward arc slot and a 0-capacity reverse slot. Returns the ABI arrays
    (numpy-compatible jnp arrays) with preflow *not* applied.
    """
    assert n <= V, f"graph ({n}) exceeds variant capacity ({V})"
    rows = [[] for _ in range(V)]  # per-vertex [(target, cap, eid, fwd)]
    for i, (u, v, c) in enumerate(edges):
        rows[u].append([v, float(c), i, True])
        rows[v].append([u, 0.0, i, False])
    nbr = [[0] * D for _ in range(V)]
    mask = [[0.0] * D for _ in range(V)]
    cf = [[0.0] * D for _ in range(V)]
    rev = [[0] * D for _ in range(V)]
    slot_of = {}
    for u in range(V):
        assert len(rows[u]) <= D, f"vertex {u} degree {len(rows[u])} exceeds D={D}"
        for i, (v, c, eid, fwd) in enumerate(rows[u]):
            nbr[u][i] = v
            mask[u][i] = 1.0
            cf[u][i] = c
            slot_of[(eid, fwd)] = u * D + i
    for (eid, fwd), flat in slot_of.items():
        rev[flat // D][flat % D] = slot_of[(eid, not fwd)]
    e = [0.0] * V
    h = [0] * V
    excl = [0.0] * V
    excl[s] = 1.0
    excl[t] = 1.0
    h[s] = n
    return (
        jnp.array(nbr, dtype=jnp.int32),
        jnp.array(rev, dtype=jnp.int32),
        jnp.array(mask, dtype=jnp.float32),
        jnp.array(cf, dtype=jnp.float32),
        jnp.array(e, dtype=jnp.float32),
        jnp.array(h, dtype=jnp.int32),
        jnp.array(excl, dtype=jnp.float32),
        jnp.array([n], dtype=jnp.int32),
    )


def preflow(nbr, mask, cf, rev, e, s):
    """Saturate the source's outgoing arcs (Alg. 1 step 0). Returns
    (cf, e, excess_total)."""
    V, D = cf.shape
    src_slots = (jnp.arange(V) == s)[:, None] & (mask > 0)
    amounts = jnp.where(src_slots, cf, 0.0)
    total = amounts.sum()
    cf1 = cf - amounts
    rev_flat = rev.reshape(-1)
    cf2 = cf1.reshape(-1).at[rev_flat].add(amounts.reshape(-1)).reshape(V, D)
    tgt = nbr.reshape(-1)
    e1 = e.reshape(-1 if e.ndim > 1 else e.shape[0])
    e2 = e1.at[tgt].add(amounts.reshape(-1))
    return cf2, e2, float(total)
