"""AOT lowering: jax/Pallas (L2+L1) -> HLO text + manifest, consumed by the
rust runtime (`rust/src/runtime/`).

HLO *text* is the interchange format, NOT serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default variant set: (V, D, K, tile). Keep in sync with
# rust/src/runtime/artifact.rs expectations (read from the manifest).
# Tile sizes picked by the §Perf sweep (EXPERIMENTS.md): interpret-mode
# cycle cost at V=1024 is 702 us/cycle with tile=128, 419 with tile=512,
# and regresses again at 1024 — mid-size tiles amortize the per-program
# overhead without paying the huge-gather cliff.
VARIANTS = [
    {"v": 64, "d": 8, "k": 16, "tile": 64},
    {"v": 256, "d": 16, "k": 32, "tile": 256},
    {"v": 1024, "d": 32, "k": 64, "tile": 512},
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_relabel_variant(v: int, d: int, k: int, tile: int) -> str:
    """Lower the device-side global relabel (extension kernel)."""
    f32 = jnp.float32
    i32 = jnp.int32
    spec = jax.ShapeDtypeStruct
    args = (
        spec((v, d), i32),   # nbr
        spec((v, d), f32),   # mask
        spec((v, d), f32),   # cf
        spec((v,), i32),     # dist
    )

    def fn(nbr, mask, cf, dist):
        return model.run_relabel(nbr, mask, cf, dist, cycles=k, tile=tile)

    lowered = jax.jit(fn, donate_argnums=(3,)).lower(*args)
    return to_hlo_text(lowered)


def lower_variant(v: int, d: int, k: int, tile: int) -> str:
    """Lower run_cycles for one (V, D, K) variant to HLO text."""
    f32 = jnp.float32
    i32 = jnp.int32
    spec = jax.ShapeDtypeStruct
    args = (
        spec((v, d), i32),   # nbr
        spec((v, d), i32),   # rev
        spec((v, d), f32),   # mask
        spec((v, d), f32),   # cf
        spec((v,), f32),     # e
        spec((v,), i32),     # h
        spec((v,), f32),     # excl
        spec((1,), i32),     # nreal
    )

    def fn(nbr, rev, mask, cf, e, h, excl, nreal):
        return model.run_cycles(nbr, rev, mask, cf, e, h, excl, nreal, cycles=k, tile=tile)

    # Donate the mutable carry (cf, e, h) so XLA updates in place.
    lowered = jax.jit(fn, donate_argnums=(3, 4, 5)).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="", help="comma list like 64x8x16; empty = defaults")
    args = ap.parse_args()

    variants = VARIANTS
    if args.variants:
        variants = []
        for spec_str in args.variants.split(","):
            v, d, k = (int(x) for x in spec_str.split("x"))
            variants.append({"v": v, "d": d, "k": k, "tile": min(v, 128)})

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "abi": 1, "variants": []}
    for spec_v in variants:
        v, d, k, tile = spec_v["v"], spec_v["d"], spec_v["k"], spec_v["tile"]
        for kind in ("flow", "relabel"):
            if kind == "flow":
                name = f"wbpr_v{v}_d{d}_k{k}"
                text = lower_variant(v, d, k, tile)
                io = {
                    "inputs": ["nbr[v,d]i32", "rev[v,d]i32", "mask[v,d]f32", "cf[v,d]f32",
                               "e[v]f32", "h[v]i32", "excl[v]f32", "nreal[1]i32"],
                    "outputs": ["cf[v,d]f32", "e[v]f32", "h[v]i32", "active[1]i32"],
                }
            else:
                name = f"wbpr_gr_v{v}_d{d}_k{k}"
                text = lower_relabel_variant(v, d, k, tile)
                io = {
                    "inputs": ["nbr[v,d]i32", "mask[v,d]f32", "cf[v,d]f32", "dist[v]i32"],
                    "outputs": ["dist[v]i32", "changed[1]i32"],
                }
            print(f"lowering {name} (tile={tile}) ...", flush=True)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest["variants"].append(
                {"name": name, "file": f"{name}.hlo.txt", "kind": kind, "v": v, "d": d, "k": k,
                 "tile": tile, "sha256_16": digest, **io}
            )
            print(f"  wrote {path} ({len(text)} chars)")
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {mpath} with {len(manifest['variants'])} variants")


if __name__ == "__main__":
    sys.exit(main())
