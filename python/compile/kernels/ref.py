"""Pure-jnp oracle for the WBPR device step — the correctness reference the
Pallas kernel is tested against (and the executable spec of the device ABI
documented in DESIGN.md §7).

State lives in a degree-padded (ELLPACK-style) layout, the TPU analog of the
paper's BCSR (see DESIGN.md §Hardware-Adaptation):

  nbr[V, D]  int32  neighbor vertex id per slot (0 for padding)
  rev[V, D]  int32  flat index (v*D + i') of the reverse slot
  mask[V, D] f32    1.0 where the slot holds a real residual arc
  cf[V, D]   f32    residual capacity per slot
  e[V]       f32    excess per vertex
  h[V]       int32  height per vertex
  excl[V]    f32    1.0 for source/sink (never active)
  nreal[1]   int32  height cap (= number of real vertices)

One step = the bulk-synchronous form of Algorithm 1's local operation:
every active vertex finds its min-height residual neighbor (the paper's
warp reduction -> here a lane-axis reduction), then pushes or relabels;
all updates are computed from the pre-step state and applied at once
(a legal schedule of the lock-free algorithm — see DESIGN.md §5).
"""

import jax.numpy as jnp

BIG = 1 << 30  # plain int: pallas kernels must not capture traced constants


def proposals(nbr, mask, cf, e, h, excl, nreal):
    """The kernel's job: per-vertex push/relabel proposals.

    Returns (d, j, newh):
      d[V]    f32  push amount (0 where no push)
      j[V]    i32  chosen slot (argmin-height neighbor), -1 where no push
      newh[V] i32  new heights (relabels applied; unchanged elsewhere)
    """
    valid = (mask > 0) & (cf > 0)
    nh = jnp.where(valid, h[nbr], BIG)  # gather neighbor heights
    minh = nh.min(axis=1)
    j = nh.argmin(axis=1).astype(jnp.int32)
    has = valid.any(axis=1)
    eligible = (e > 0) & (h < nreal) & (excl == 0)
    active = eligible & has
    can_push = active & (h > minh)
    cf_sel = jnp.take_along_axis(cf, j[:, None], axis=1)[:, 0]
    d = jnp.where(can_push, jnp.minimum(e, cf_sel), 0.0).astype(cf.dtype)
    relabel = active & ~can_push
    dead = eligible & ~has  # no residual arc at all: deactivate
    newh = jnp.where(relabel, minh + 1, h)
    newh = jnp.where(dead, nreal + 1, newh).astype(h.dtype)
    j = jnp.where(can_push, j, -1)
    return d, j, newh


def apply_proposals(nbr, rev, cf, e, d, j, newh):
    """Scatter-combine of the proposals (the 'atomics' of Alg. 1 lines
    15-19, as a deterministic bulk-synchronous step)."""
    V, D = cf.shape
    push = j >= 0
    jc = jnp.clip(j, 0, D - 1)
    amount = jnp.where(push, d, 0.0)
    onehot = (jnp.arange(D, dtype=jnp.int32)[None, :] == jc[:, None]) & push[:, None]
    cf1 = cf - onehot * amount[:, None]
    rev_sel = jnp.take_along_axis(rev, jc[:, None], axis=1)[:, 0]
    cf2 = cf1.reshape(-1).at[rev_sel].add(amount).reshape(V, D)
    tgt = jnp.take_along_axis(nbr, jc[:, None], axis=1)[:, 0]
    e1 = e - amount
    e2 = e1.at[tgt].add(amount)
    return cf2, e2, newh


def step(nbr, rev, mask, cf, e, h, excl, nreal):
    """One full BSP push-relabel iteration (proposals + combine)."""
    d, j, newh = proposals(nbr, mask, cf, e, h, excl, nreal)
    return apply_proposals(nbr, rev, cf, e, d, j, newh)


def active_count(cf, e, h, excl, nreal, mask):
    """Vertices still active (Alg. 1 line 9), for the host's early exit."""
    has = ((mask > 0) & (cf > 0)).any(axis=1)
    act = (e > 0) & (h < nreal) & (excl == 0) & has
    return act.sum(dtype=jnp.int32)


def run_cycles(nbr, rev, mask, cf, e, h, excl, nreal, cycles):
    """`cycles` BSP iterations (python loop — used by tests; the AOT path
    uses model.run_cycles with lax.fori_loop)."""
    for _ in range(cycles):
        cf, e, h = step(nbr, rev, mask, cf, e, h, excl, nreal)
    return cf, e, h


# ---------------------------------------------------------------------------
# Global relabel (extension): backward BFS from the sink as an iterative
# min-plus relaxation. dist(u) relaxes over residual arcs u->v (cf > 0):
# dist(u) = min(dist(u), 1 + min_v dist(v)); the sink is pinned by its
# initial 0. A fixpoint equals the exact BFS distance-to-sink, i.e. the
# height labeling Alg. 1's GlobalRelabel() computes on the CPU.
# ---------------------------------------------------------------------------


def relabel_step(nbr, mask, cf, dist):
    """One min-plus relaxation sweep. Returns (dist', changed_count)."""
    valid = (mask > 0) & (cf > 0)
    nd = jnp.where(valid, dist[nbr], BIG)
    cand = nd.min(axis=1) + 1
    new = jnp.minimum(dist, cand).astype(dist.dtype)
    changed = (new != dist).sum(dtype=jnp.int32)
    return new, changed


def relabel_fixpoint(nbr, mask, cf, dist, max_iters=None):
    """Iterate to fixpoint (python loop, tests only)."""
    iters = max_iters if max_iters is not None else int(dist.shape[0]) + 1
    for _ in range(iters):
        dist, changed = relabel_step(nbr, mask, cf, dist)
        if int(changed) == 0:
            break
    return dist
