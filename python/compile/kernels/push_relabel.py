"""L1 — the WBPR push-relabel local operation as a Pallas kernel.

The paper's hot spot is the per-active-vertex min-height-neighbor search
(Alg. 2's second-level parallelism: one warp per vertex, tree reduction).
On TPU that becomes: tile the degree-padded neighbor matrix into VMEM rows,
reduce along the lane axis (`jnp.min`/`argmin` lower to VPU tree
reductions), and emit per-vertex push/relabel *proposals*; the surrounding
L2 jax program applies them with XLA scatters (the deterministic stand-in
for CUDA atomics — DESIGN.md §Hardware-Adaptation).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO, which is what the rust
runtime loads. Real-TPU viability is argued via the VMEM budget in
DESIGN.md §9.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1 << 30  # plain int: pallas kernels must not capture traced constants


def _proposal_kernel(nbr_ref, mask_ref, cf_ref, e_ref, h_ref, excl_ref, hfull_ref, n_ref,
                     d_ref, j_ref, newh_ref):
    """One vertex tile: min-height-neighbor reduction + push/relabel choice.

    Block layout per grid step i (T = tile rows, D = padded degree):
      nbr/mask/cf: [T, D] VMEM tiles  (the BCSR-row analog)
      e/h/excl:    [T]     per-vertex state
      hfull:       [V]     the full height vector, broadcast to every tile
                           (the 'shared memory' of the paper's reduction)
      n:           [1]     height cap (scalar prefetch)
    """
    nbr = nbr_ref[...]
    mask = mask_ref[...]
    cf = cf_ref[...]
    e = e_ref[...]
    h = h_ref[...]
    excl = excl_ref[...]
    hfull = hfull_ref[...]
    n = n_ref[0]

    valid = (mask > 0) & (cf > 0)
    # Gather neighbor heights; padding gathers hfull[0] but is masked to BIG.
    nh = jnp.where(valid, hfull[nbr], BIG)
    # Lane-axis tree reduction (the warp parallel reduction, Harris k7).
    minh = nh.min(axis=1)
    j = nh.argmin(axis=1).astype(jnp.int32)
    has = valid.any(axis=1)

    eligible = (e > 0) & (h < n) & (excl == 0)
    active = eligible & has
    can_push = active & (h > minh)
    cf_sel = jnp.take_along_axis(cf, j[:, None], axis=1)[:, 0]

    d_ref[...] = jnp.where(can_push, jnp.minimum(e, cf_sel), 0.0).astype(cf.dtype)
    j_ref[...] = jnp.where(can_push, j, -1)
    newh = jnp.where(active & ~can_push, minh + 1, h)
    newh = jnp.where(eligible & ~has, n + 1, newh).astype(h.dtype)
    newh_ref[...] = newh


@functools.partial(jax.jit, static_argnames=("tile",))
def proposals(nbr, mask, cf, e, h, excl, nreal, *, tile=0):
    """Pallas-call wrapper: per-vertex (d, j, newh) proposals.

    `tile` = rows per grid step (0 = whole array in one tile). V must be a
    multiple of `tile`.
    """
    V, D = nbr.shape
    T = tile if tile else V
    assert V % T == 0, f"V={V} not a multiple of tile={T}"
    grid = (V // T,)
    row_spec = pl.BlockSpec((T, D), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((T,), lambda i: (i,))
    full_spec = pl.BlockSpec((V,), lambda i: (0,))
    one_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _proposal_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, vec_spec, vec_spec, vec_spec, full_spec, one_spec],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((V,), cf.dtype),
            jax.ShapeDtypeStruct((V,), jnp.int32),
            jax.ShapeDtypeStruct((V,), jnp.int32),
        ],
        interpret=True,
    )(nbr, mask, cf, e, h, excl, h, nreal)


def _min_reduce_kernel(x_ref, mask_ref, o_ref):
    x = x_ref[...]
    m = mask_ref[...]
    o_ref[...] = jnp.where(m > 0, x, BIG).min(axis=1)


@functools.partial(jax.jit, static_argnames=("tile",))
def masked_min_rows(x, mask, *, tile=0):
    """Micro-kernel: masked per-row min — the isolated reduction primitive
    (benchmarked standalone as the paper benchmarks Harris kernel 7)."""
    V, D = x.shape
    T = tile if tile else V
    assert V % T == 0
    return pl.pallas_call(
        _min_reduce_kernel,
        grid=(V // T,),
        in_specs=[pl.BlockSpec((T, D), lambda i: (i, 0)), pl.BlockSpec((T, D), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((T,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((V,), x.dtype),
        interpret=True,
    )(x, mask)


def _relabel_kernel(nbr_ref, mask_ref, cf_ref, distfull_ref, dist_ref, o_ref):
    """Global-relabel relaxation tile: dist'(u) = min(dist(u),
    1 + min over residual slots of dist(neighbor)) — the device-side form
    of Alg. 1's GlobalRelabel() backward BFS (see ref.relabel_step)."""
    nbr = nbr_ref[...]
    mask = mask_ref[...]
    cf = cf_ref[...]
    distfull = distfull_ref[...]
    dist = dist_ref[...]
    valid = (mask > 0) & (cf > 0)
    nd = jnp.where(valid, distfull[nbr], BIG)
    o_ref[...] = jnp.minimum(dist, nd.min(axis=1) + 1).astype(dist.dtype)


@functools.partial(jax.jit, static_argnames=("tile",))
def relabel_step(nbr, mask, cf, dist, *, tile=0):
    """One relaxation sweep as a Pallas call. Returns (dist', changed)."""
    V, D = nbr.shape
    T = tile if tile else V
    assert V % T == 0
    row_spec = pl.BlockSpec((T, D), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((T,), lambda i: (i,))
    full_spec = pl.BlockSpec((V,), lambda i: (0,))
    new = pl.pallas_call(
        _relabel_kernel,
        grid=(V // T,),
        in_specs=[row_spec, row_spec, row_spec, full_spec, vec_spec],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((V,), dist.dtype),
        interpret=True,
    )(nbr, mask, cf, dist, dist)
    changed = (new != dist).sum(dtype=jnp.int32)
    return new, changed


def vmem_bytes(V, D):
    """Estimated VMEM footprint of one tile invocation with T=V rows:
    3 [V,D] f32/i32 tiles + 4 [V] vectors + the broadcast hfull.
    Used by the §9 roofline discussion and checked in tests."""
    return 3 * V * D * 4 + 4 * V * 4 + V * 4
